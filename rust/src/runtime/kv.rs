//! Zero-copy KV-cache views: the read side of the backend seam.
//!
//! A [`KvView`] is a borrowed, `cache_len`-bounded window over the
//! coordinator's KV slabs (`coordinator::kv_cache::KvPool`). Since the
//! shared-prefix refactor a lane's cache is no longer necessarily one
//! contiguous region: each lane is described by a sorted run of
//! [`KvSeg`]s, every segment mapping a contiguous position range onto a
//! `[L, H, region_len, dh]` region of the slabs. Two layouts exist in
//! practice:
//!
//! * **private slot** — one segment covering the whole sequence (the
//!   pre-refactor layout; every closed-batch engine still sees exactly
//!   this);
//! * **chained** — the prompt positions map onto ref-counted,
//!   block-granular prefix pages shared with other lanes (the prefix
//!   cache), and the generated positions map onto the lane's private
//!   slot at their natural offsets.
//!
//! Creating a view copies no cache data either way: a view is the two
//! slab borrows plus the per-lane lane table. For private-slot batches
//! up to [`INLINE_LANES`] lanes the table is an inline base-offset
//! array, so building a view — one per program call on the decode hot
//! path — performs **zero** heap allocations; chained or oversized
//! batches fall back to a heap-backed segment table (the prefix-cache
//! path, off the hotpath gate and documented as such). Engines hand
//! views straight to the backend every program call; backends that
//! execute on the host (the reference backend) read individual
//! positions through the accessors, and backends that need a device
//! layout (PJRT) materialize the batch-major `[L, bs, H, S, dh]` buffer
//! behind the seam with [`KvView::to_batch_major`] — the one place a
//! full copy still exists, and only for that backend.
//!
//! `cache_len` is the lockstep valid-prefix length: positions
//! `>= cache_len` are stale slab content (slots are not zeroed on free)
//! and reads there are a bug the debug assertions catch.

use super::tensor::TensorF32;
use crate::util::kernels;

/// Per-slot layout dimensions: one lane's slot is `[L, H, S, dh]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvDims {
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub d_head: usize,
}

impl KvDims {
    pub fn of(geom: &super::manifest::Geometry) -> KvDims {
        KvDims {
            n_layers: geom.n_layers,
            n_heads: geom.n_heads,
            seq_len: geom.seq_len,
            d_head: geom.d_head,
        }
    }

    /// Elements in one lane's slot.
    pub fn slot_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.seq_len * self.d_head
    }
}

/// One contiguous piece of a lane's cache: positions
/// `[start, start + len)` live in the `[L, H, region_len, dh]` region
/// that begins at element `base`, where position `start` maps to
/// region-local position `offset`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvSeg {
    pub start: usize,
    pub len: usize,
    pub base: usize,
    pub region_len: usize,
    pub offset: usize,
}

impl KvSeg {
    /// The classic whole-slot layout: one `[L, H, seq_len, dh]` region
    /// serving every position at its natural offset.
    pub fn full_slot(base: usize, seq_len: usize) -> KvSeg {
        KvSeg { start: 0, len: seq_len, base, region_len: seq_len, offset: 0 }
    }
}

/// One lane's segment run (heap-backed lane table only).
enum LaneMap {
    One(KvSeg),
    Many(Vec<KvSeg>),
}

impl LaneMap {
    #[inline]
    fn segs(&self) -> &[KvSeg] {
        match self {
            LaneMap::One(s) => std::slice::from_ref(s),
            LaneMap::Many(v) => v,
        }
    }
}

/// Largest private-slot batch whose lane table stays inline (no heap
/// allocation per view). Serving buckets top out at 4 lanes and eval
/// closed batches at 32 slots but ≤16 lanes per cohort; bigger batches
/// still work through the heap fallback.
pub const INLINE_LANES: usize = 16;

/// Per-view lane table: inline whole-slot bases or borrowed segment
/// runs on the hot path, a heap-backed segment run everywhere else.
enum LaneTable<'a> {
    Plain { bases: [usize; INLINE_LANES], bs: usize },
    /// Borrowed per-lane segment runs (the paged pool lends its cached
    /// runs): zero-allocation like `Plain`, but page-table aware.
    Inline { segs: [&'a [KvSeg]; INLINE_LANES], bs: usize },
    Segmented(Vec<LaneMap>),
}

/// Borrowed view of a batch's KV caches: segmented lane maps over the
/// slabs, valid-prefix bounded. See the module docs for the layout
/// contract.
pub struct KvView<'a> {
    k: &'a [f32],
    v: &'a [f32],
    lanes: LaneTable<'a>,
    dims: KvDims,
    cache_len: usize,
}

impl<'a> KvView<'a> {
    /// Build a view over classic one-slot-per-lane layouts.
    /// `bases[lane]` is the element offset of that lane's `[L, H, S,
    /// dh]` slot; every slot must fit inside both slabs. Allocation-free
    /// for batches up to [`INLINE_LANES`] lanes.
    pub fn new(
        k: &'a [f32],
        v: &'a [f32],
        bases: &[usize],
        dims: KvDims,
        cache_len: usize,
    ) -> KvView<'a> {
        debug_assert!(cache_len <= dims.seq_len, "cache_len beyond slot");
        if bases.len() <= INLINE_LANES {
            let mut inline = [0usize; INLINE_LANES];
            inline[..bases.len()].copy_from_slice(bases);
            #[cfg(debug_assertions)]
            for &b in bases {
                debug_assert!(
                    b + dims.slot_elems() <= k.len()
                        && b + dims.slot_elems() <= v.len(),
                    "slot outside the slabs"
                );
            }
            return KvView {
                k,
                v,
                lanes: LaneTable::Plain { bases: inline, bs: bases.len() },
                dims,
                cache_len,
            };
        }
        let lanes = bases
            .iter()
            .map(|&b| LaneMap::One(KvSeg::full_slot(b, dims.seq_len)))
            .collect();
        Self::build(k, v, lanes, dims, cache_len)
    }

    /// Build a view that *borrows* per-lane segment runs (the paged
    /// pool lends its cached runs): allocation-free for batches up to
    /// [`INLINE_LANES`] lanes, with the same segment contract as
    /// [`KvView::segmented`]. Oversized batches fall back to the
    /// heap-backed table by cloning the runs.
    pub fn inline(
        k: &'a [f32],
        v: &'a [f32],
        lanes: &[&'a [KvSeg]],
        dims: KvDims,
        cache_len: usize,
    ) -> KvView<'a> {
        debug_assert!(cache_len <= dims.seq_len, "cache_len beyond slot");
        if lanes.len() <= INLINE_LANES {
            let mut segs: [&'a [KvSeg]; INLINE_LANES] = [&[]; INLINE_LANES];
            segs[..lanes.len()].copy_from_slice(lanes);
            #[cfg(debug_assertions)]
            for lane in lanes {
                let mut next = 0usize;
                for s in lane.iter() {
                    debug_assert_eq!(
                        s.start, next,
                        "segments must be contiguous"
                    );
                    debug_assert!(s.len > 0, "empty KV segment");
                    debug_assert!(
                        s.offset + s.len <= s.region_len,
                        "segment overruns its region"
                    );
                    let end = s.base
                        + dims.n_layers
                            * dims.n_heads
                            * s.region_len
                            * dims.d_head;
                    debug_assert!(
                        end <= k.len() && end <= v.len(),
                        "segment region outside the slabs"
                    );
                    next += s.len;
                }
                debug_assert!(
                    next >= cache_len,
                    "segments do not cover cache_len"
                );
            }
            return KvView {
                k,
                v,
                lanes: LaneTable::Inline { segs, bs: lanes.len() },
                dims,
                cache_len,
            };
        }
        let lanes = lanes.iter().map(|s| s.to_vec()).collect();
        Self::segmented(k, v, lanes, dims, cache_len)
    }

    /// Build a view from explicit per-lane segment runs (the shared-
    /// prefix layout). Segments must be sorted, contiguous from
    /// position 0, and cover at least `cache_len` positions.
    pub fn segmented(
        k: &'a [f32],
        v: &'a [f32],
        lanes: Vec<Vec<KvSeg>>,
        dims: KvDims,
        cache_len: usize,
    ) -> KvView<'a> {
        let lanes = lanes
            .into_iter()
            .map(|segs| {
                if segs.len() == 1 {
                    LaneMap::One(segs[0])
                } else {
                    LaneMap::Many(segs)
                }
            })
            .collect();
        Self::build(k, v, lanes, dims, cache_len)
    }

    fn build(
        k: &'a [f32],
        v: &'a [f32],
        lanes: Vec<LaneMap>,
        dims: KvDims,
        cache_len: usize,
    ) -> KvView<'a> {
        debug_assert!(cache_len <= dims.seq_len, "cache_len beyond slot");
        #[cfg(debug_assertions)]
        for lane in &lanes {
            let mut next = 0usize;
            for s in lane.segs() {
                debug_assert_eq!(s.start, next, "segments must be contiguous");
                debug_assert!(s.len > 0, "empty KV segment");
                debug_assert!(
                    s.offset + s.len <= s.region_len,
                    "segment overruns its region"
                );
                let end = s.base
                    + dims.n_layers * dims.n_heads * s.region_len * dims.d_head;
                debug_assert!(
                    end <= k.len() && end <= v.len(),
                    "segment region outside the slabs"
                );
                next += s.len;
            }
            debug_assert!(next >= cache_len, "segments do not cover cache_len");
        }
        KvView { k, v, lanes: LaneTable::Segmented(lanes), dims, cache_len }
    }

    /// Number of lanes in the view.
    pub fn bs(&self) -> usize {
        match &self.lanes {
            LaneTable::Plain { bs, .. } => *bs,
            LaneTable::Inline { bs, .. } => *bs,
            LaneTable::Segmented(lanes) => lanes.len(),
        }
    }

    /// Valid-prefix length: positions `< cache_len` are committed.
    pub fn cache_len(&self) -> usize {
        self.cache_len
    }

    pub fn dims(&self) -> KvDims {
        self.dims
    }

    #[inline]
    fn idx(&self, lane: usize, l: usize, h: usize, pos: usize, d: usize) -> usize {
        debug_assert!(pos < self.cache_len, "read past valid prefix");
        let g = &self.dims;
        let segs = match &self.lanes {
            LaneTable::Plain { bases, bs } => {
                debug_assert!(lane < *bs, "lane out of range");
                // whole-slot lanes: pure offset arithmetic, no table walk
                return bases[lane]
                    + ((l * g.n_heads + h) * g.seq_len + pos) * g.d_head
                    + d;
            }
            LaneTable::Inline { segs, bs } => {
                debug_assert!(lane < *bs, "lane out of range");
                segs[lane]
            }
            LaneTable::Segmented(lanes) => lanes[lane].segs(),
        };
        // multi-segment (chained) lanes guess the segment from the
        // uniform page length — exact for pool-built runs (equal-length
        // pages then the tail) — and fall back to a scan for arbitrary
        // layouts
        let seg = if segs.len() == 1 {
            &segs[0]
        } else {
            let guess = (pos / segs[0].len).min(segs.len() - 1);
            let s = &segs[guess];
            if pos >= s.start && pos < s.start + s.len {
                s
            } else {
                segs.iter()
                    .find(|s| pos >= s.start && pos < s.start + s.len)
                    .expect("position not covered by any KV segment")
            }
        };
        seg.base
            + ((l * g.n_heads + h) * seg.region_len + seg.offset
                + (pos - seg.start))
                * g.d_head
            + d
    }

    /// One K element at `(lane, layer, head, pos, feature)`.
    #[inline]
    pub fn k_at(&self, lane: usize, l: usize, h: usize, pos: usize, d: usize) -> f32 {
        self.k[self.idx(lane, l, h, pos, d)]
    }

    /// One V element at `(lane, layer, head, pos, feature)`.
    #[inline]
    pub fn v_at(&self, lane: usize, l: usize, h: usize, pos: usize, d: usize) -> f32 {
        self.v[self.idx(lane, l, h, pos, d)]
    }

    /// Materialize the batch-major `[L, bs, H, S, dh]` K/V pair the AOT
    /// programs consume. This is the full copy the engines no longer
    /// perform; only device backends (PJRT) pay it, behind the seam.
    /// Shared prefix segments are copied once per lane here — the price
    /// of the device layout, not of the shared pool. Head rows have
    /// uniform strides on both sides within a layer, so the widening is
    /// one 2-D SIMD kernel copy per (layer, segment) instead of
    /// per-(layer, head) index recomputation.
    pub fn to_batch_major(&self) -> (TensorF32, TensorF32) {
        let g = &self.dims;
        let (l_n, h_n, s_n, dh) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let bs = self.bs();
        let mut k = TensorF32::zeros(&[l_n, bs, h_n, s_n, dh]);
        let mut v = TensorF32::zeros(&[l_n, bs, h_n, s_n, dh]);
        let mut copy_seg = |lane: usize, seg: &KvSeg| {
            let run = seg.len * dh;
            for l in 0..l_n {
                let src =
                    seg.base + (l * h_n * seg.region_len + seg.offset) * dh;
                let dst = ((l * bs + lane) * h_n * s_n + seg.start) * dh;
                kernels::copy_2d(
                    &mut k.data,
                    dst,
                    s_n * dh,
                    self.k,
                    src,
                    seg.region_len * dh,
                    h_n,
                    run,
                );
                kernels::copy_2d(
                    &mut v.data,
                    dst,
                    s_n * dh,
                    self.v,
                    src,
                    seg.region_len * dh,
                    h_n,
                    run,
                );
            }
        };
        match &self.lanes {
            LaneTable::Plain { bases, bs } => {
                for (lane, &b) in bases[..*bs].iter().enumerate() {
                    copy_seg(lane, &KvSeg::full_slot(b, s_n));
                }
            }
            LaneTable::Inline { segs, bs } => {
                for (lane, run) in segs[..*bs].iter().enumerate() {
                    for seg in run.iter() {
                        copy_seg(lane, seg);
                    }
                }
            }
            LaneTable::Segmented(lanes) => {
                for (lane, map) in lanes.iter().enumerate() {
                    for seg in map.segs() {
                        copy_seg(lane, seg);
                    }
                }
            }
        }
        (k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> KvDims {
        KvDims { n_layers: 2, n_heads: 2, seq_len: 4, d_head: 3 }
    }

    #[test]
    fn view_reads_lane_major_slots() {
        let d = dims();
        let n = d.slot_elems();
        // two slots: slot 0 holds its flat index, slot 1 holds +1000
        let mut k: Vec<f32> = (0..n).map(|i| i as f32).collect();
        k.extend((0..n).map(|i| 1000.0 + i as f32));
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        // lanes swapped relative to slot order
        let view = KvView::new(&k, &v, &[n, 0], d, 4);
        assert_eq!(view.bs(), 2);
        // lane 0 reads slot 1's content
        assert_eq!(view.k_at(0, 0, 0, 0, 0), 1000.0);
        // lane 1, layer 1, head 1, pos 3, feat 2 = last element of slot 0
        assert_eq!(view.k_at(1, 1, 1, 3, 2), (n - 1) as f32);
        assert_eq!(view.v_at(1, 0, 0, 0, 0), 0.5);
    }

    #[test]
    fn oversized_plain_batches_fall_back_to_segment_table() {
        let d = dims();
        let n = d.slot_elems();
        let lanes = INLINE_LANES + 3;
        let k: Vec<f32> = (0..lanes * n).map(|i| i as f32).collect();
        let v = k.clone();
        let bases: Vec<usize> = (0..lanes).map(|i| i * n).collect();
        let view = KvView::new(&k, &v, &bases, d, 4);
        assert_eq!(view.bs(), lanes);
        for lane in 0..lanes {
            assert_eq!(view.k_at(lane, 0, 0, 0, 0), (lane * n) as f32);
            assert_eq!(view.k_at(lane, 1, 1, 3, 2), (lane * n + n - 1) as f32);
        }
    }

    #[test]
    fn segmented_view_stitches_pages_and_tail() {
        let d = dims();
        // one shared page covering positions 0..2 ([L, H, 2, dh]) placed
        // after a full slot in the same slab
        let slot_elems = d.slot_elems();
        let page_elems = d.n_layers * d.n_heads * 2 * d.d_head;
        let mut k = vec![0.0f32; slot_elems + page_elems];
        // slot content: flat index; page content: +5000
        for (i, x) in k.iter_mut().enumerate().take(slot_elems) {
            *x = i as f32;
        }
        for i in 0..page_elems {
            k[slot_elems + i] = 5000.0 + i as f32;
        }
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        let segs = vec![
            KvSeg { start: 0, len: 2, base: slot_elems, region_len: 2, offset: 0 },
            KvSeg { start: 2, len: 2, base: 0, region_len: 4, offset: 2 },
        ];
        let view = KvView::segmented(&k, &v, vec![segs], d, 4);
        // pos 0..2 come from the page: page-local (l, h, pos, f)
        assert_eq!(view.k_at(0, 0, 0, 0, 0), 5000.0);
        // (l=1, h=1, pos=1, f=2) -> page-local ((3 * 2) + 1) * 3 + 2 = 23
        assert_eq!(view.k_at(0, 1, 1, 1, 2), 5023.0);
        // pos 2..4 come from the slot at natural offsets
        assert_eq!(view.k_at(0, 0, 0, 2, 0), 6.0);
        assert_eq!(view.v_at(0, 0, 0, 3, 1), -10.0);
    }

    #[test]
    fn inline_view_matches_segmented_view() {
        let d = dims();
        let slot_elems = d.slot_elems();
        let page_elems = d.n_layers * d.n_heads * 2 * d.d_head;
        let mut k = vec![0.0f32; slot_elems + page_elems];
        for (i, x) in k.iter_mut().enumerate() {
            *x = i as f32;
        }
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        let run = [
            KvSeg {
                start: 0,
                len: 2,
                base: slot_elems,
                region_len: 2,
                offset: 0,
            },
            KvSeg { start: 2, len: 2, base: 0, region_len: 4, offset: 2 },
        ];
        let borrowed: [&[KvSeg]; 1] = [&run];
        let inline = KvView::inline(&k, &v, &borrowed, d, 4);
        let heap = KvView::segmented(&k, &v, vec![run.to_vec()], d, 4);
        assert_eq!(inline.bs(), 1);
        for l in 0..d.n_layers {
            for h in 0..d.n_heads {
                for pos in 0..4 {
                    for f in 0..d.d_head {
                        assert_eq!(
                            inline.k_at(0, l, h, pos, f),
                            heap.k_at(0, l, h, pos, f)
                        );
                        assert_eq!(
                            inline.v_at(0, l, h, pos, f),
                            heap.v_at(0, l, h, pos, f)
                        );
                    }
                }
            }
        }
        let (ik, _) = inline.to_batch_major();
        let (hk, _) = heap.to_batch_major();
        assert_eq!(ik.data, hk.data);
    }

    #[test]
    fn batch_major_materialization_matches_accessors() {
        let d = dims();
        let n = d.slot_elems();
        let page_elems = d.n_layers * d.n_heads * 2 * d.d_head;
        let mut k: Vec<f32> = (0..2 * n).map(|i| i as f32).collect();
        k.extend((0..page_elems).map(|i| 9000.0 + i as f32));
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        // lane 0: plain slot 0; lane 1: shared page + slot-1 tail
        let lanes = vec![
            vec![KvSeg::full_slot(0, 4)],
            vec![
                KvSeg { start: 0, len: 2, base: 2 * n, region_len: 2, offset: 0 },
                KvSeg { start: 2, len: 2, base: n, region_len: 4, offset: 2 },
            ],
        ];
        let view = KvView::segmented(&k, &v, lanes, d, 4);
        let (bk, bv) = view.to_batch_major();
        assert_eq!(bk.shape, vec![2, 2, 2, 4, 3]);
        for lane in 0..2 {
            for l in 0..2 {
                for h in 0..2 {
                    for pos in 0..4 {
                        for f in 0..3 {
                            let idx = ((((l * 2 + lane) * 2 + h) * 4) + pos)
                                * 3
                                + f;
                            assert_eq!(
                                bk.data[idx],
                                view.k_at(lane, l, h, pos, f)
                            );
                            assert_eq!(
                                bv.data[idx],
                                view.v_at(lane, l, h, pos, f)
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "valid prefix")]
    fn reads_past_cache_len_are_caught() {
        let d = dims();
        let k = vec![0.0; d.slot_elems()];
        let v = vec![0.0; d.slot_elems()];
        let view = KvView::new(&k, &v, &[0], d, 2);
        view.k_at(0, 0, 0, 2, 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "contiguous")]
    fn gapped_segments_are_caught() {
        let d = dims();
        let k = vec![0.0; 2 * d.slot_elems()];
        let v = vec![0.0; 2 * d.slot_elems()];
        let segs = vec![
            KvSeg { start: 0, len: 1, base: 0, region_len: 4, offset: 0 },
            KvSeg { start: 2, len: 2, base: 0, region_len: 4, offset: 2 },
        ];
        let _ = KvView::segmented(&k, &v, vec![segs], d, 3);
    }
}
