//! Model weights: npz loading in the manifest's canonical argument order.
//!
//! Weights are uploaded as the leading arguments of every AOT program.
//! They are loaded once per model and shared (Arc) across engines.

use std::path::Path;

use anyhow::Result;
use xla::FromRawBytes;

use super::manifest::Manifest;

pub struct ModelWeights {
    pub name: String,
    /// Literals in manifest `weight_names` order.
    pub literals: Vec<xla::Literal>,
    /// Persistent device buffers (uploaded once; §Perf optimization #4:
    /// avoids re-copying ~1.2 MB of weights host->device on every
    /// decode step). Populated by `upload`.
    pub buffers: Option<Vec<xla::PjRtBuffer>>,
    pub total_params: usize,
}

impl ModelWeights {
    pub fn load(manifest: &Manifest, model: &str) -> Result<ModelWeights> {
        let file = manifest
            .model_weight_file(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{model}'"))?;
        Self::load_file(&manifest.dir.join(file), &manifest.weight_names, model)
    }

    pub fn load_file(
        path: &Path,
        weight_names: &[String],
        name: &str,
    ) -> Result<ModelWeights> {
        let mut arrays = xla::Literal::read_npz(path, &())?;
        arrays.sort_by(|a, b| a.0.cmp(&b.0));
        let names: Vec<&String> = arrays.iter().map(|(n, _)| n).collect();
        anyhow::ensure!(
            names.len() == weight_names.len()
                && names.iter().zip(weight_names).all(|(a, b)| *a == b),
            "weight names in {} do not match manifest order",
            path.display()
        );
        let mut total = 0usize;
        let literals: Vec<xla::Literal> = arrays
            .into_iter()
            .map(|(_, l)| {
                total += l.element_count();
                l
            })
            .collect();
        Ok(ModelWeights {
            name: name.to_string(),
            literals,
            buffers: None,
            total_params: total,
        })
    }

    /// Upload the weights to device buffers once (subsequent executes
    /// use `execute_b` and skip the per-call host->device weight copy).
    /// Disabled by CDLM_NO_DEVICE_WEIGHTS=1 (the §Perf A/B switch).
    pub fn upload(&mut self, rt: &super::Runtime) -> Result<()> {
        if self.buffers.is_some()
            || std::env::var_os("CDLM_NO_DEVICE_WEIGHTS").is_some()
        {
            return Ok(());
        }
        let bufs = self
            .literals
            .iter()
            .map(|l| rt.to_device(l))
            .collect::<Result<Vec<_>>>()?;
        self.buffers = Some(bufs);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_all_declared_models() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        for (model, _) in &m.models {
            let w = ModelWeights::load(&m, model).unwrap();
            assert_eq!(w.literals.len(), m.weight_names.len());
            assert!(w.total_params > 10_000, "{model}: {}", w.total_params);
        }
    }

    #[test]
    fn unknown_model_errors() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(ModelWeights::load(&m, "nope").is_err());
    }
}
