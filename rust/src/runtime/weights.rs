//! Model weights handle: the per-model identity engines bind to.
//!
//! The handle itself is backend-agnostic metadata — name, a stable
//! 64-bit seed derived from the name (the reference backend's notion of
//! "which parameters"), and the parameter count implied by the manifest
//! geometry. The PJRT backend resolves the name to its npz literals
//! internally; nothing above the backend seam touches array data.

use anyhow::Result;

use super::backend::Backend;
use super::manifest::Manifest;

pub struct ModelWeights {
    pub name: String,
    /// Stable content seed (FNV-1a of the model name): two models never
    /// share a seed, so reference-backend decodes differ per model.
    pub seed: u64,
    pub total_params: usize,
}

impl ModelWeights {
    pub fn load(manifest: &Manifest, model: &str) -> Result<ModelWeights> {
        anyhow::ensure!(
            manifest.model_weight_file(model).is_some(),
            "unknown model '{model}'"
        );
        let g = &manifest.geometry;
        // gated MLP: wg/wu (d x f) + wd (f x d), matching
        // python/compile/model.py::param_shapes
        let per_layer = 4 * g.d_model * g.d_model
            + 3 * g.d_model * g.d_ff
            + 2 * g.d_model;
        let total_params = 2 * g.vocab_size * g.d_model
            + g.n_layers * per_layer
            + g.d_model;
        Ok(ModelWeights {
            name: model.to_string(),
            seed: fnv1a(model.as_bytes()),
            total_params,
        })
    }

    /// Make the weights device-resident (backend-dependent; a no-op on
    /// the reference backend).
    pub fn upload(&self, rt: &super::Runtime) -> Result<()> {
        rt.backend().upload(self)
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn loads_all_declared_models() {
        let m = Manifest::reference(Path::new("ref"));
        let mut seeds = Vec::new();
        for (model, _) in &m.models {
            let w = ModelWeights::load(&m, model).unwrap();
            assert_eq!(w.name, *model);
            assert!(w.total_params > 10_000, "{model}: {}", w.total_params);
            seeds.push(w.seed);
        }
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), m.models.len(), "model seeds must be distinct");
    }

    #[test]
    fn unknown_model_errors() {
        let m = Manifest::reference(Path::new("ref"));
        assert!(ModelWeights::load(&m, "nope").is_err());
    }

    #[test]
    fn seed_is_stable_across_calls() {
        let m = Manifest::reference(Path::new("ref"));
        let a = ModelWeights::load(&m, "cdlm_dream").unwrap();
        let b = ModelWeights::load(&m, "cdlm_dream").unwrap();
        assert_eq!(a.seed, b.seed);
        let c = ModelWeights::load(&m, "ar_dream").unwrap();
        assert_ne!(a.seed, c.seed);
    }
}
