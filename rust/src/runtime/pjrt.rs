//! PJRT runtime: load HLO-text artifacts, compile once, execute from the
//! serving hot path.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax>=0.5
//! serialized protos — the text parser reassigns instruction ids; see
//! /opt/xla-example/README.md). Executables are compiled lazily on first
//! use and cached for the lifetime of the runtime; `warmup()` pre-compiles
//! the hot set so serving latency is flat from the first request.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::manifest::Manifest;

/// Key into the executable cache.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ProgramKey {
    pub name: String,
    pub bs: usize,
    pub block: Option<usize>,
}

impl ProgramKey {
    pub fn new(name: &str, bs: usize, block: Option<usize>) -> Self {
        Self { name: name.to_string(), bs, block }
    }
}

pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: Mutex<HashMap<ProgramKey, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    pub compile_log: Mutex<Vec<(String, f64)>>,
}

impl Runtime {
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            manifest,
            client,
            executables: Mutex::new(HashMap::new()),
            compile_log: Mutex::new(Vec::new()),
        })
    }

    /// Compile (or fetch cached) an AOT program.
    pub fn executable(
        &self,
        key: &ProgramKey,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.lock().unwrap().get(key) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .find_program(&key.name, key.bs, key.block)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "program {}(bs={}, block={:?}) not in manifest",
                    key.name,
                    key.bs,
                    key.block
                )
            })?;
        let path = self.manifest.dir.join(&entry.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf8 path"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.compile_log
            .lock()
            .unwrap()
            .push((entry.file.clone(), t0.elapsed().as_secs_f64()));
        self.executables.lock().unwrap().insert(key.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute a program: weights first, then `inputs`; returns the
    /// decomposed output tuple.
    pub fn run(
        &self,
        key: &ProgramKey,
        weights: &[xla::Literal],
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let trace = std::env::var_os("CDLM_TRACE").is_some();
        let t0 = Instant::now();
        let exe = self.executable(key)?;
        let t_compile = t0.elapsed();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(weights.len() + inputs.len());
        args.extend(weights.iter());
        args.extend(inputs.iter().copied());
        let t1 = Instant::now();
        let out = exe.execute::<&xla::Literal>(&args)?;
        let t_exec = t1.elapsed();
        let t2 = Instant::now();
        let lit = out[0][0].to_literal_sync()?;
        let parsed = lit.to_tuple()?;
        if trace {
            eprintln!(
                "[trace] {}(bs={}) compile/fetch {:?} exec {:?} fetch {:?}",
                key.name, key.bs, t_compile, t_exec, t2.elapsed()
            );
        }
        Ok(parsed)
    }

    /// Host literal -> device buffer (for persistent weight residency).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Execute with device-resident weight buffers (`execute_b`): only
    /// the per-step inputs are copied host->device.
    pub fn run_with_buffers(
        &self,
        key: &ProgramKey,
        weight_bufs: &[xla::PjRtBuffer],
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let trace = std::env::var_os("CDLM_TRACE").is_some();
        let exe = self.executable(key)?;
        let input_bufs = inputs
            .iter()
            .map(|l| self.to_device(l))
            .collect::<Result<Vec<_>>>()?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(weight_bufs.len() + input_bufs.len());
        args.extend(weight_bufs.iter());
        args.extend(input_bufs.iter());
        let t1 = Instant::now();
        let out = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let t_exec = t1.elapsed();
        let lit = out[0][0].to_literal_sync()?;
        if trace {
            eprintln!(
                "[trace] {}(bs={}) exec_b {:?}",
                key.name, key.bs, t_exec
            );
        }
        Ok(lit.to_tuple()?)
    }

    /// Pre-compile the given programs (serving warm-up).
    pub fn warmup(&self, keys: &[ProgramKey]) -> Result<()> {
        for k in keys {
            self.executable(k)?;
        }
        Ok(())
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.lock().unwrap().len()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_and_compiles_lazily() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.compiled_count(), 0);
        let key = ProgramKey::new("teacher_denoise", 1, None);
        rt.executable(&key).unwrap();
        assert_eq!(rt.compiled_count(), 1);
        // cached: second call does not recompile
        rt.executable(&key).unwrap();
        assert_eq!(rt.compiled_count(), 1);
        assert_eq!(rt.compile_log.lock().unwrap().len(), 1);
    }

    #[test]
    fn missing_program_is_an_error() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        assert!(rt
            .executable(&ProgramKey::new("nonexistent", 1, None))
            .is_err());
    }
}
