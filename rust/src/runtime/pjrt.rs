//! PJRT execution path: load HLO-text artifacts, compile once, execute
//! from the serving hot path. Compiled only with the `pjrt` cargo
//! feature (requires the offline `xla` crate closure); the default
//! build uses the deterministic reference backend instead.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax>=0.5
//! serialized protos — the text parser reassigns instruction ids).
//! Executables are compiled lazily on first use and cached for the
//! lifetime of the backend; `warmup()` pre-compiles the hot set so
//! serving latency is flat from the first request.
//!
//! Known cost of the backend seam: KV caches cross it as borrowed
//! `KvView`s over the coordinator's lane-major slabs, and the AOT
//! programs consume batch-major `[L, bs, H, S, dh]` buffers — so each
//! block/step call materializes the batch-major pair here
//! (`KvView::to_batch_major`) before building the cache literals. That
//! copy used to live in every engine's decode loop (`gather_batch`);
//! it now exists only behind this seam, and only for this backend. If
//! the §Perf profile shows literal churn dominating again, add a
//! per-(model, shape) scratch-literal cache here — behind the seam,
//! not in the engines.

/// Key into a backend's executable cache.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ProgramKey {
    pub name: String,
    pub bs: usize,
    pub block: Option<usize>,
}

impl ProgramKey {
    pub fn new(name: &str, bs: usize, block: Option<usize>) -> Self {
        Self { name: name.to_string(), bs, block }
    }
}

#[cfg(feature = "pjrt")]
pub use client::PjrtBackend;

// Mutex locks in this module unwrap poison deliberately: a poisoned
// backend mutex means a decode panicked mid-call, and the supervisor
// quarantines the owning core instead of ever reusing it — so
// propagating the original panic is the designed outcome, not a new
// failure mode worth a softer error path.
#[cfg(feature = "pjrt")]
#[allow(clippy::unwrap_used)]
mod client {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    use anyhow::Result;

    use super::ProgramKey;
    use crate::runtime::backend::Backend;
    use crate::runtime::kv::KvView;
    use crate::runtime::manifest::Manifest;
    use crate::runtime::programs::{
        ArPrefillOut, ArStepOut, BlockStepOut, DenoiseOut, FullCacheOut,
        PrefillOut,
    };
    use crate::runtime::tensor::{scalar_i32, TensorF32, TensorI32};
    use crate::runtime::weights::ModelWeights;

    /// PJRT-backed executor: owns the CPU client, the compiled
    /// executable cache, per-model weight literals loaded from the
    /// manifest's npz files, and (after `upload`) persistent device
    /// buffers — §Perf optimization #4: avoids re-copying every
    /// parameter tensor host->device on each decode step. Residency
    /// is disabled by CDLM_NO_DEVICE_WEIGHTS=1 (the §Perf A/B switch).
    pub struct PjrtBackend {
        manifest: Manifest,
        client: xla::PjRtClient,
        executables: Mutex<HashMap<ProgramKey, Arc<xla::PjRtLoadedExecutable>>>,
        weights: Mutex<HashMap<String, Arc<Vec<xla::Literal>>>>,
        device_weights: Mutex<HashMap<String, Arc<Vec<xla::PjRtBuffer>>>>,
        pub compile_log: Mutex<Vec<(String, f64)>>,
        /// First thread to execute a program; `run()` asserts every
        /// later execution stays on it (the unsafe Send/Sync contract).
        exec_thread: Mutex<Option<std::thread::ThreadId>>,
    }

    // SAFETY: the PJRT C API is documented thread-compatible and every
    // interior-mutable member is Mutex-guarded, but the xla crate's
    // client handles are not `Send`/`Sync` themselves. The serving
    // architecture therefore still confines this backend to the single
    // decode-worker thread: `max_concurrency()` reports 1, which keeps
    // the parallel chunk/group executors on the serial path (both
    // fan-out sites clamp to it), so no program call ever crosses a
    // thread in practice — and `run()` debug-asserts that affinity on
    // every execution. These impls only satisfy the
    // `Backend: Send + Sync` bound the reference backend needs for
    // real parallelism.
    unsafe impl Send for PjrtBackend {}
    unsafe impl Sync for PjrtBackend {}

    impl PjrtBackend {
        pub fn load(manifest: &Manifest) -> Result<PjrtBackend> {
            Ok(PjrtBackend {
                manifest: manifest.clone(),
                client: xla::PjRtClient::cpu()?,
                executables: Mutex::new(HashMap::new()),
                weights: Mutex::new(HashMap::new()),
                device_weights: Mutex::new(HashMap::new()),
                compile_log: Mutex::new(Vec::new()),
                exec_thread: Mutex::new(None),
            })
        }

        fn executable(
            &self,
            key: &ProgramKey,
        ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
            if let Some(e) = self.executables.lock().unwrap().get(key) {
                return Ok(e.clone());
            }
            let entry = self
                .manifest
                .find_program(&key.name, key.bs, key.block)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "program {}(bs={}, block={:?}) not in manifest",
                        key.name,
                        key.bs,
                        key.block
                    )
                })?;
            let path = self.manifest.dir.join(&entry.file);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("utf8 path"),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = Arc::new(self.client.compile(&comp)?);
            self.compile_log
                .lock()
                .unwrap()
                .push((entry.file.clone(), t0.elapsed().as_secs_f64()));
            self.executables.lock().unwrap().insert(key.clone(), exe.clone());
            Ok(exe)
        }

        fn model_literals(
            &self,
            w: &ModelWeights,
        ) -> Result<Arc<Vec<xla::Literal>>> {
            use xla::FromRawBytes;
            if let Some(l) = self.weights.lock().unwrap().get(&w.name) {
                return Ok(l.clone());
            }
            let file = self
                .manifest
                .model_weight_file(&w.name)
                .ok_or_else(|| anyhow::anyhow!("unknown model '{}'", w.name))?;
            let mut arrays =
                xla::Literal::read_npz(&self.manifest.dir.join(file), &())?;
            arrays.sort_by(|a, b| a.0.cmp(&b.0));
            anyhow::ensure!(
                arrays.len() == self.manifest.weight_names.len()
                    && arrays
                        .iter()
                        .zip(&self.manifest.weight_names)
                        .all(|((a, _), b)| a == b),
                "weight names in {file} do not match manifest order"
            );
            let lits =
                Arc::new(arrays.into_iter().map(|(_, l)| l).collect::<Vec<_>>());
            self.weights.lock().unwrap().insert(w.name.clone(), lits.clone());
            Ok(lits)
        }

        /// Execute a program: weights first, then `inputs`; returns the
        /// decomposed output tuple. Prefers device-resident weight
        /// buffers (`execute_b`) when `upload` has installed them —
        /// only the per-step inputs are then copied host->device.
        fn run(
            &self,
            w: &ModelWeights,
            key: &ProgramKey,
            inputs: &[&xla::Literal],
        ) -> Result<Vec<xla::Literal>> {
            {
                // enforce the single-thread contract behind the unsafe
                // Send/Sync impls: all executions on one thread
                let mut owner = self.exec_thread.lock().unwrap();
                let me = std::thread::current().id();
                match *owner {
                    None => *owner = Some(me),
                    Some(t) => debug_assert_eq!(
                        t, me,
                        "PjrtBackend program call crossed threads"
                    ),
                }
            }
            let trace = std::env::var_os("CDLM_TRACE").is_some();
            let exe = self.executable(key)?;
            let resident = self.device_weights.lock().unwrap().get(&w.name).cloned();
            let t1 = Instant::now();
            let lit = match resident {
                Some(bufs) => {
                    let input_bufs = inputs
                        .iter()
                        .map(|l| {
                            Ok(self.client.buffer_from_host_literal(None, l)?)
                        })
                        .collect::<Result<Vec<_>>>()?;
                    let mut args: Vec<&xla::PjRtBuffer> =
                        Vec::with_capacity(bufs.len() + input_bufs.len());
                    args.extend(bufs.iter());
                    args.extend(input_bufs.iter());
                    let out = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
                    out[0][0].to_literal_sync()?
                }
                None => {
                    let weights = self.model_literals(w)?;
                    let mut args: Vec<&xla::Literal> =
                        Vec::with_capacity(weights.len() + inputs.len());
                    args.extend(weights.iter());
                    args.extend(inputs.iter().copied());
                    let out = exe.execute::<&xla::Literal>(&args)?;
                    out[0][0].to_literal_sync()?
                }
            };
            if trace {
                eprintln!(
                    "[trace] {}(bs={}) exec {:?}",
                    key.name,
                    key.bs,
                    t1.elapsed()
                );
            }
            Ok(lit.to_tuple()?)
        }
    }

    impl Backend for PjrtBackend {
        fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn compiled_count(&self) -> usize {
            self.executables.lock().unwrap().len()
        }

        fn max_concurrency(&self) -> usize {
            1 // single decode-worker thread; see the Send/Sync note above
        }

        fn warmup(&self, keys: &[ProgramKey]) -> Result<()> {
            for k in keys {
                self.executable(k)?;
            }
            Ok(())
        }

        fn upload(&self, weights: &ModelWeights) -> Result<()> {
            if std::env::var_os("CDLM_NO_DEVICE_WEIGHTS").is_some()
                || self.device_weights.lock().unwrap().contains_key(&weights.name)
            {
                return Ok(());
            }
            let lits = self.model_literals(weights)?;
            let bufs = lits
                .iter()
                .map(|l| Ok(self.client.buffer_from_host_literal(None, l)?))
                .collect::<Result<Vec<_>>>()?;
            self.device_weights
                .lock()
                .unwrap()
                .insert(weights.name.clone(), Arc::new(bufs));
            Ok(())
        }

        fn teacher_denoise(
            &self,
            w: &ModelWeights,
            bs: usize,
            ids: &TensorI32,
            valid_from: &TensorI32,
            out: &mut DenoiseOut,
        ) -> Result<()> {
            let key = ProgramKey::new("teacher_denoise", bs, None);
            let a = ids.to_literal()?;
            let b = valid_from.to_literal()?;
            let res = self.run(w, &key, &[&a, &b])?;
            out.tok = TensorI32::from_literal(&res[1])?;
            out.conf = TensorF32::from_literal(&res[2])?;
            let dense = TensorF32::from_literal(&res[0])?;
            out.logits.set_from_dense(
                &dense.data,
                &out.tok.data,
                self.manifest.geometry.vocab_size,
            );
            Ok(())
        }

        fn teacher_full_cache(
            &self,
            w: &ModelWeights,
            bs: usize,
            ids: &TensorI32,
            valid_from: &TensorI32,
            out: &mut FullCacheOut,
        ) -> Result<()> {
            let key = ProgramKey::new("teacher_full_cache", bs, None);
            let a = ids.to_literal()?;
            let b = valid_from.to_literal()?;
            let res = self.run(w, &key, &[&a, &b])?;
            out.tok = TensorI32::from_literal(&res[1])?;
            out.conf = TensorF32::from_literal(&res[2])?;
            out.k = TensorF32::from_literal(&res[3])?;
            out.v = TensorF32::from_literal(&res[4])?;
            let dense = TensorF32::from_literal(&res[0])?;
            out.logits.set_from_dense(
                &dense.data,
                &out.tok.data,
                self.manifest.geometry.vocab_size,
            );
            Ok(())
        }

        fn teacher_block_approx(
            &self,
            w: &ModelWeights,
            bs: usize,
            block: usize,
            kv: &KvView<'_>,
            valid_from: &TensorI32,
            blk_ids: &TensorI32,
            pos0: i32,
            out: &mut BlockStepOut,
        ) -> Result<()> {
            let key = ProgramKey::new("teacher_block_approx", bs, Some(block));
            let (k_cache, v_cache) = kv.to_batch_major();
            let kc = k_cache.to_literal()?;
            let vc = v_cache.to_literal()?;
            let vf = valid_from.to_literal()?;
            let blk = blk_ids.to_literal()?;
            let p0 = scalar_i32(pos0);
            let res = self.run(w, &key, &[&kc, &vc, &vf, &blk, &p0])?;
            self.parse_block_step(res, out)
        }

        fn student_prefill(
            &self,
            w: &ModelWeights,
            bs: usize,
            prompt_ids: &TensorI32,
            valid_from: &TensorI32,
            out: &mut PrefillOut,
        ) -> Result<()> {
            let key = ProgramKey::new("student_prefill", bs, None);
            let a = prompt_ids.to_literal()?;
            let b = valid_from.to_literal()?;
            let res = self.run(w, &key, &[&a, &b])?;
            out.k = TensorF32::from_literal(&res[0])?;
            out.v = TensorF32::from_literal(&res[1])?;
            Ok(())
        }

        fn student_block_step(
            &self,
            w: &ModelWeights,
            bs: usize,
            block: usize,
            kv: &KvView<'_>,
            valid_from: &TensorI32,
            blk_ids: &TensorI32,
            pos0: i32,
            out: &mut BlockStepOut,
        ) -> Result<()> {
            let key = ProgramKey::new("student_block_step", bs, Some(block));
            let (k_cache, v_cache) = kv.to_batch_major();
            let kc = k_cache.to_literal()?;
            let vc = v_cache.to_literal()?;
            let cl = scalar_i32(kv.cache_len() as i32);
            let vf = valid_from.to_literal()?;
            let blk = blk_ids.to_literal()?;
            let p0 = scalar_i32(pos0);
            let res = self.run(w, &key, &[&kc, &vc, &cl, &vf, &blk, &p0])?;
            self.parse_block_step(res, out)
        }

        fn ar_verify(
            &self,
            w: &ModelWeights,
            bs: usize,
            block: usize,
            kv: &KvView<'_>,
            valid_from: &TensorI32,
            blk_ids: &TensorI32,
            pos0: i32,
            out: &mut BlockStepOut,
        ) -> Result<()> {
            let key = ProgramKey::new("ar_verify", bs, Some(block));
            let (k_cache, v_cache) = kv.to_batch_major();
            let kc = k_cache.to_literal()?;
            let vc = v_cache.to_literal()?;
            let cl = scalar_i32(kv.cache_len() as i32);
            let vf = valid_from.to_literal()?;
            let blk = blk_ids.to_literal()?;
            let p0 = scalar_i32(pos0);
            let res = self.run(w, &key, &[&kc, &vc, &cl, &vf, &blk, &p0])?;
            self.parse_block_step(res, out)
        }

        fn ar_prefill(
            &self,
            w: &ModelWeights,
            bs: usize,
            prompt_ids: &TensorI32,
            valid_from: &TensorI32,
            out: &mut ArPrefillOut,
        ) -> Result<()> {
            let key = ProgramKey::new("ar_prefill", bs, None);
            let a = prompt_ids.to_literal()?;
            let b = valid_from.to_literal()?;
            let res = self.run(w, &key, &[&a, &b])?;
            out.tok = TensorI32::from_literal(&res[1])?;
            out.conf = TensorF32::from_literal(&res[2])?;
            out.k = TensorF32::from_literal(&res[3])?;
            out.v = TensorF32::from_literal(&res[4])?;
            let dense = TensorF32::from_literal(&res[0])?;
            out.logits.set_from_dense(
                &dense.data,
                &out.tok.data,
                self.manifest.geometry.vocab_size,
            );
            Ok(())
        }

        fn ar_step(
            &self,
            w: &ModelWeights,
            bs: usize,
            kv: &KvView<'_>,
            valid_from: &TensorI32,
            tok_ids: &TensorI32,
            out: &mut ArStepOut,
        ) -> Result<()> {
            let key = ProgramKey::new("ar_step", bs, None);
            let (k_cache, v_cache) = kv.to_batch_major();
            let kc = k_cache.to_literal()?;
            let vc = v_cache.to_literal()?;
            let cl = scalar_i32(kv.cache_len() as i32);
            let vf = valid_from.to_literal()?;
            let t = tok_ids.to_literal()?;
            let res = self.run(w, &key, &[&kc, &vc, &cl, &vf, &t])?;
            out.tok = TensorI32::from_literal(&res[1])?;
            out.conf = TensorF32::from_literal(&res[2])?;
            out.k1 = TensorF32::from_literal(&res[3])?;
            out.v1 = TensorF32::from_literal(&res[4])?;
            let dense = TensorF32::from_literal(&res[0])?;
            out.logits.set_from_dense(
                &dense.data,
                &out.tok.data,
                self.manifest.geometry.vocab_size,
            );
            Ok(())
        }
    }

    impl PjrtBackend {
        /// Decompose a block-step program's output tuple into the
        /// caller's struct, reducing the dense logits to the sparse peak
        /// representation at the seam (the logit at each row's argmax
        /// token — exactly what `ProposalLogits` carries).
        fn parse_block_step(
            &self,
            res: Vec<xla::Literal>,
            out: &mut BlockStepOut,
        ) -> Result<()> {
            out.tok = TensorI32::from_literal(&res[1])?;
            out.conf = TensorF32::from_literal(&res[2])?;
            out.k_blk = TensorF32::from_literal(&res[3])?;
            out.v_blk = TensorF32::from_literal(&res[4])?;
            let dense = TensorF32::from_literal(&res[0])?;
            out.logits.set_from_dense(
                &dense.data,
                &out.tok.data,
                self.manifest.geometry.vocab_size,
            );
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_keys_hash_and_compare() {
        let a = ProgramKey::new("student_block_step", 1, Some(8));
        let b = ProgramKey::new("student_block_step", 1, Some(8));
        let c = ProgramKey::new("student_block_step", 2, Some(8));
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut set = std::collections::HashSet::new();
        set.insert(a);
        assert!(set.contains(&b));
        assert!(!set.contains(&c));
    }
}
