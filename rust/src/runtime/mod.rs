//! Runtime: the bridge from model artifacts to the serving hot path.
//!
//! [`Runtime`] owns a [`Manifest`] and a boxed [`Backend`]; `Programs`
//! exposes typed call wrappers for every AOT program entry point. Two
//! backends implement the seam: the deterministic pure-Rust
//! [`ReferenceBackend`] (default, artifact-free) and the PJRT/XLA path
//! (`pjrt` cargo feature, requires `make artifacts`). Python is never
//! on the request path — the artifacts directory is the entire
//! contract, and when it is absent the built-in reference manifest
//! stands in.

pub mod arena;
pub mod backend;
pub mod kv;
pub mod manifest;
pub mod pjrt;
pub mod programs;
pub mod reference;
pub mod tensor;
pub mod weights;

pub use arena::StepArena;
pub use backend::{Backend, Runtime};
pub use kv::{KvDims, KvSeg, KvView, INLINE_LANES};
pub use manifest::{Geometry, Manifest};
pub use pjrt::ProgramKey;
pub use programs::{ProposalLogits, Programs};
pub use reference::ReferenceBackend;
pub use tensor::{TensorF32, TensorI32};
pub use weights::ModelWeights;
