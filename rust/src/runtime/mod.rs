//! Runtime: the bridge from AOT artifacts to the serving hot path.
//!
//! `Runtime` owns the PJRT CPU client and the compiled-executable cache;
//! `ModelWeights` holds a model's parameter literals in the manifest's
//! canonical order; `Programs` exposes typed call wrappers for every AOT
//! program. Python is never on this path — the artifacts directory is
//! the entire contract.

pub mod manifest;
pub mod pjrt;
pub mod programs;
pub mod tensor;
pub mod weights;

pub use manifest::{Geometry, Manifest};
pub use pjrt::{ProgramKey, Runtime};
pub use programs::Programs;
pub use tensor::{TensorF32, TensorI32};
pub use weights::ModelWeights;
