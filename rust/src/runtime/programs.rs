//! Typed wrappers over the AOT program set.
//!
//! Each wrapper builds the input literal list (weights first — the
//! manifest's canonical order), executes, and parses the output tuple
//! into host tensors. Output tuple orders are fixed by the L2 function
//! signatures in `python/compile/model.py`.

use anyhow::Result;

use super::pjrt::{ProgramKey, Runtime};
use super::tensor::{scalar_i32, TensorF32, TensorI32};
use super::weights::ModelWeights;

/// One refinement step over every sequence position (vanilla teacher).
pub struct DenoiseOut {
    pub logits: TensorF32, // [bs, S, V]
    pub tok: TensorI32,    // [bs, S]
    pub conf: TensorF32,   // [bs, S]
}

/// Full step that also returns the KV stacks (approx-cache refresh).
pub struct FullCacheOut {
    pub logits: TensorF32,
    pub tok: TensorI32,
    pub conf: TensorF32,
    pub k: TensorF32, // [L, bs, H, S, dh]
    pub v: TensorF32,
}

/// Block-scoped step (student exact-cache / teacher approx-cache).
pub struct BlockStepOut {
    pub logits: TensorF32, // [bs, B, V]
    pub tok: TensorI32,    // [bs, B]
    pub conf: TensorF32,   // [bs, B]
    pub k_blk: TensorF32,  // [L, bs, H, B, dh]
    pub v_blk: TensorF32,
}

pub struct PrefillOut {
    pub k: TensorF32, // [L, bs, H, P, dh]
    pub v: TensorF32,
}

pub struct ArPrefillOut {
    pub logits: TensorF32, // [bs, V]
    pub tok: TensorI32,    // [bs]
    pub conf: TensorF32,   // [bs]
    pub k: TensorF32,
    pub v: TensorF32,
}

pub struct ArStepOut {
    pub logits: TensorF32, // [bs, V]
    pub tok: TensorI32,
    pub conf: TensorF32,
    pub k1: TensorF32, // [L, bs, H, 1, dh]
    pub v1: TensorF32,
}

/// Program set bound to one model's weights.
pub struct Programs<'rt> {
    pub rt: &'rt Runtime,
    pub weights: &'rt ModelWeights,
}

impl<'rt> Programs<'rt> {
    pub fn new(rt: &'rt Runtime, weights: &'rt ModelWeights) -> Self {
        Self { rt, weights }
    }

    fn run(&self, key: &ProgramKey, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        // §Perf: prefer device-resident weights when uploaded (skips the
        // per-call host->device copy of every parameter tensor)
        match &self.weights.buffers {
            Some(bufs) => self.rt.run_with_buffers(key, bufs, inputs),
            None => self.rt.run(key, &self.weights.literals, inputs),
        }
    }

    pub fn teacher_denoise(
        &self,
        bs: usize,
        ids: &TensorI32,         // [bs, S]
        valid_from: &TensorI32,  // [bs]
    ) -> Result<DenoiseOut> {
        let key = ProgramKey::new("teacher_denoise", bs, None);
        let a = ids.to_literal()?;
        let b = valid_from.to_literal()?;
        let out = self.run(&key, &[&a, &b])?;
        Ok(DenoiseOut {
            logits: TensorF32::from_literal(&out[0])?,
            tok: TensorI32::from_literal(&out[1])?,
            conf: TensorF32::from_literal(&out[2])?,
        })
    }

    pub fn teacher_full_cache(
        &self,
        bs: usize,
        ids: &TensorI32,
        valid_from: &TensorI32,
    ) -> Result<FullCacheOut> {
        let key = ProgramKey::new("teacher_full_cache", bs, None);
        let a = ids.to_literal()?;
        let b = valid_from.to_literal()?;
        let out = self.run(&key, &[&a, &b])?;
        Ok(FullCacheOut {
            logits: TensorF32::from_literal(&out[0])?,
            tok: TensorI32::from_literal(&out[1])?,
            conf: TensorF32::from_literal(&out[2])?,
            k: TensorF32::from_literal(&out[3])?,
            v: TensorF32::from_literal(&out[4])?,
        })
    }

    #[allow(clippy::too_many_arguments)]
    pub fn teacher_block_approx(
        &self,
        bs: usize,
        block: usize,
        k_cache: &xla::Literal,
        v_cache: &xla::Literal,
        valid_from: &TensorI32,
        blk_ids: &TensorI32, // [bs, B]
        pos0: i32,
    ) -> Result<BlockStepOut> {
        let key = ProgramKey::new("teacher_block_approx", bs, Some(block));
        let vf = valid_from.to_literal()?;
        let blk = blk_ids.to_literal()?;
        let p0 = scalar_i32(pos0);
        let out = self.run(&key, &[k_cache, v_cache, &vf, &blk, &p0])?;
        parse_block_step(out)
    }

    pub fn student_prefill(
        &self,
        bs: usize,
        prompt_ids: &TensorI32, // [bs, P]
        valid_from: &TensorI32,
    ) -> Result<PrefillOut> {
        let key = ProgramKey::new("student_prefill", bs, None);
        let a = prompt_ids.to_literal()?;
        let b = valid_from.to_literal()?;
        let out = self.run(&key, &[&a, &b])?;
        Ok(PrefillOut {
            k: TensorF32::from_literal(&out[0])?,
            v: TensorF32::from_literal(&out[1])?,
        })
    }

    #[allow(clippy::too_many_arguments)]
    pub fn student_block_step(
        &self,
        bs: usize,
        block: usize,
        k_cache: &xla::Literal,
        v_cache: &xla::Literal,
        cache_len: i32,
        valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
    ) -> Result<BlockStepOut> {
        let key = ProgramKey::new("student_block_step", bs, Some(block));
        let cl = scalar_i32(cache_len);
        let vf = valid_from.to_literal()?;
        let blk = blk_ids.to_literal()?;
        let p0 = scalar_i32(pos0);
        let out = self.run(&key, &[k_cache, v_cache, &cl, &vf, &blk, &p0])?;
        parse_block_step(out)
    }

    /// Parallel AR verification of a drafted block (Appendix C
    /// speculative-decoding extension): causal teacher-forcing over the
    /// drafted tokens against the AR cache.
    #[allow(clippy::too_many_arguments)]
    pub fn ar_verify(
        &self,
        bs: usize,
        block: usize,
        k_cache: &xla::Literal,
        v_cache: &xla::Literal,
        cache_len: i32,
        valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
    ) -> Result<BlockStepOut> {
        let key = ProgramKey::new("ar_verify", bs, Some(block));
        let cl = scalar_i32(cache_len);
        let vf = valid_from.to_literal()?;
        let blk = blk_ids.to_literal()?;
        let p0 = scalar_i32(pos0);
        let out = self.run(&key, &[k_cache, v_cache, &cl, &vf, &blk, &p0])?;
        parse_block_step(out)
    }

    pub fn ar_prefill(
        &self,
        bs: usize,
        prompt_ids: &TensorI32,
        valid_from: &TensorI32,
    ) -> Result<ArPrefillOut> {
        let key = ProgramKey::new("ar_prefill", bs, None);
        let a = prompt_ids.to_literal()?;
        let b = valid_from.to_literal()?;
        let out = self.run(&key, &[&a, &b])?;
        Ok(ArPrefillOut {
            logits: TensorF32::from_literal(&out[0])?,
            tok: TensorI32::from_literal(&out[1])?,
            conf: TensorF32::from_literal(&out[2])?,
            k: TensorF32::from_literal(&out[3])?,
            v: TensorF32::from_literal(&out[4])?,
        })
    }

    #[allow(clippy::too_many_arguments)]
    pub fn ar_step(
        &self,
        bs: usize,
        k_cache: &xla::Literal,
        v_cache: &xla::Literal,
        cache_len: i32,
        valid_from: &TensorI32,
        tok_ids: &TensorI32, // [bs]
    ) -> Result<ArStepOut> {
        let key = ProgramKey::new("ar_step", bs, None);
        let cl = scalar_i32(cache_len);
        let vf = valid_from.to_literal()?;
        let t = tok_ids.to_literal()?;
        let out = self.run(&key, &[k_cache, v_cache, &cl, &vf, &t])?;
        Ok(ArStepOut {
            logits: TensorF32::from_literal(&out[0])?,
            tok: TensorI32::from_literal(&out[1])?,
            conf: TensorF32::from_literal(&out[2])?,
            k1: TensorF32::from_literal(&out[3])?,
            v1: TensorF32::from_literal(&out[4])?,
        })
    }
}

fn parse_block_step(out: Vec<xla::Literal>) -> Result<BlockStepOut> {
    Ok(BlockStepOut {
        logits: TensorF32::from_literal(&out[0])?,
        tok: TensorI32::from_literal(&out[1])?,
        conf: TensorF32::from_literal(&out[2])?,
        k_blk: TensorF32::from_literal(&out[3])?,
        v_blk: TensorF32::from_literal(&out[4])?,
    })
}
