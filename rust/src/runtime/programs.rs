//! Typed wrappers over the AOT program set.
//!
//! `Programs` binds one model's weights to the runtime's backend and
//! exposes the eight program entry points with host-tensor signatures;
//! engines never see backend-specific types. KV caches flow through as
//! borrowed [`KvView`]s (zero-copy slab windows); everything else is a
//! host tensor. Output argument orders are fixed by the L2 function
//! signatures in `python/compile/model.py`.
//!
//! Every program is writer-style: the caller owns the output struct
//! (usually inside a [`crate::runtime::StepArena`]) and the backend
//! fills it in place, reusing the buffers via [`TensorF32::reuse`].
//! The contract is overwrite-on-reuse: for a given output shape the
//! backend rewrites every element it ever sets, so a dirty buffer from
//! the previous step is indistinguishable from a fresh one — and a
//! shape change zero-fills, so no value can leak across batch shapes.
//! Steady-state decode steps therefore perform zero heap allocations
//! (the `hotpath` bench gates this with a counting allocator).
#![allow(clippy::too_many_arguments)]

use anyhow::Result;

use super::backend::{Backend, Runtime};
use super::kv::KvView;
use super::tensor::{TensorF32, TensorI32};
use super::weights::ModelWeights;

/// Sparse per-position proposal logits.
///
/// The programs' proposal distributions cross the backend seam as one
/// `(token, logit)` peak per output row rather than a dense
/// `[rows, vocab]` tensor: no engine ever scans the vocabulary axis
/// (they consume the `tok`/`conf` projections), so materializing and
/// zeroing `rows x vocab` floats every refinement step was pure
/// allocation traffic. Device backends reduce their dense logits to
/// the same peak form at the seam; [`ProposalLogits::to_dense`]
/// recovers the dense tensor for parity tests.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProposalLogits {
    rows: usize,
    vocab: usize,
    peak_tok: Vec<i32>,
    peak_val: Vec<f32>,
}

impl ProposalLogits {
    /// Resize for reuse. Same geometry keeps the buffers (every row is
    /// rewritten by the producer); a geometry change re-zeroes.
    pub fn reuse(&mut self, rows: usize, vocab: usize) {
        if self.rows == rows && self.vocab == vocab {
            return;
        }
        self.rows = rows;
        self.vocab = vocab;
        self.peak_tok.clear();
        self.peak_tok.resize(rows, 0);
        self.peak_val.clear();
        self.peak_val.resize(rows, 0.0);
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Set row `row`'s single nonzero entry.
    pub fn set(&mut self, row: usize, tok: i32, val: f32) {
        self.peak_tok[row] = tok;
        self.peak_val[row] = val;
    }

    /// Dense lookup: the logit at `(row, tok)` — the peak value when
    /// `tok` is the row's proposal, 0.0 everywhere else.
    pub fn get(&self, row: usize, tok: i32) -> f32 {
        if self.peak_tok[row] == tok {
            self.peak_val[row]
        } else {
            0.0
        }
    }

    /// The row's `(token, logit)` peak.
    pub fn peak(&self, row: usize) -> (i32, f32) {
        (self.peak_tok[row], self.peak_val[row])
    }

    /// Materialize the dense `[rows, vocab]` tensor (tests / tooling
    /// only — never on the decode path).
    pub fn to_dense(&self) -> TensorF32 {
        let mut out = TensorF32::zeros(&[self.rows, self.vocab]);
        for r in 0..self.rows {
            let t = self.peak_tok[r];
            if t >= 0 && (t as usize) < self.vocab {
                out.data[r * self.vocab + t as usize] = self.peak_val[r];
            }
        }
        out
    }

    /// Reduce a dense `[rows, vocab]` logits buffer to peaks, taking
    /// the logit at each row's proposed token (the device-backend seam
    /// conversion; `tok` is the program's argmax output).
    pub fn set_from_dense(&mut self, dense: &[f32], tok: &[i32], vocab: usize) {
        let rows = tok.len();
        self.reuse(rows, vocab);
        for r in 0..rows {
            let t = tok[r];
            let val = if t >= 0 && (t as usize) < vocab {
                dense[r * vocab + t as usize]
            } else {
                0.0
            };
            self.set(r, t, val);
        }
    }
}

/// One refinement step over every sequence position (vanilla teacher).
#[derive(Default)]
pub struct DenoiseOut {
    pub logits: ProposalLogits, // peaks over [bs*S, V]
    pub tok: TensorI32,         // [bs, S]
    pub conf: TensorF32,        // [bs, S]
}

/// Full step that also returns the KV stacks (approx-cache refresh).
#[derive(Default)]
pub struct FullCacheOut {
    pub logits: ProposalLogits,
    pub tok: TensorI32,
    pub conf: TensorF32,
    pub k: TensorF32, // [L, bs, H, S, dh]
    pub v: TensorF32,
}

/// Block-scoped step (student exact-cache / teacher approx-cache).
#[derive(Default)]
pub struct BlockStepOut {
    pub logits: ProposalLogits, // peaks over [bs*B, V]
    pub tok: TensorI32,         // [bs, B]
    pub conf: TensorF32,        // [bs, B]
    pub k_blk: TensorF32,       // [L, bs, H, B, dh]
    pub v_blk: TensorF32,
}

#[derive(Default)]
pub struct PrefillOut {
    pub k: TensorF32, // [L, bs, H, P, dh]
    pub v: TensorF32,
}

#[derive(Default)]
pub struct ArPrefillOut {
    pub logits: ProposalLogits, // peaks over [bs, V]
    pub tok: TensorI32,         // [bs]
    pub conf: TensorF32,        // [bs]
    pub k: TensorF32,
    pub v: TensorF32,
}

#[derive(Default)]
pub struct ArStepOut {
    pub logits: ProposalLogits, // peaks over [bs, V]
    pub tok: TensorI32,
    pub conf: TensorF32,
    pub k1: TensorF32, // [L, bs, H, 1, dh]
    pub v1: TensorF32,
}

/// Program set bound to one model's weights.
pub struct Programs<'rt> {
    pub rt: &'rt Runtime,
    pub weights: &'rt ModelWeights,
}

impl<'rt> Programs<'rt> {
    pub fn new(rt: &'rt Runtime, weights: &'rt ModelWeights) -> Self {
        Self { rt, weights }
    }

    pub fn teacher_denoise(
        &self,
        bs: usize,
        ids: &TensorI32,        // [bs, S]
        valid_from: &TensorI32, // [bs]
        out: &mut DenoiseOut,
    ) -> Result<()> {
        self.rt
            .backend()
            .teacher_denoise(self.weights, bs, ids, valid_from, out)
    }

    pub fn teacher_full_cache(
        &self,
        bs: usize,
        ids: &TensorI32,
        valid_from: &TensorI32,
        out: &mut FullCacheOut,
    ) -> Result<()> {
        self.rt
            .backend()
            .teacher_full_cache(self.weights, bs, ids, valid_from, out)
    }

    pub fn teacher_block_approx(
        &self,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        blk_ids: &TensorI32, // [bs, B]
        pos0: i32,
        out: &mut BlockStepOut,
    ) -> Result<()> {
        self.rt.backend().teacher_block_approx(
            self.weights,
            bs,
            block,
            kv,
            valid_from,
            blk_ids,
            pos0,
            out,
        )
    }

    pub fn student_prefill(
        &self,
        bs: usize,
        prompt_ids: &TensorI32, // [bs, P]
        valid_from: &TensorI32,
        out: &mut PrefillOut,
    ) -> Result<()> {
        self.rt
            .backend()
            .student_prefill(self.weights, bs, prompt_ids, valid_from, out)
    }

    pub fn student_block_step(
        &self,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
        out: &mut BlockStepOut,
    ) -> Result<()> {
        self.rt.backend().student_block_step(
            self.weights,
            bs,
            block,
            kv,
            valid_from,
            blk_ids,
            pos0,
            out,
        )
    }

    /// Parallel AR verification of a drafted block (Appendix C
    /// speculative-decoding extension): causal teacher-forcing over the
    /// drafted tokens against the AR cache.
    pub fn ar_verify(
        &self,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
        out: &mut BlockStepOut,
    ) -> Result<()> {
        self.rt.backend().ar_verify(
            self.weights,
            bs,
            block,
            kv,
            valid_from,
            blk_ids,
            pos0,
            out,
        )
    }

    pub fn ar_prefill(
        &self,
        bs: usize,
        prompt_ids: &TensorI32,
        valid_from: &TensorI32,
        out: &mut ArPrefillOut,
    ) -> Result<()> {
        self.rt
            .backend()
            .ar_prefill(self.weights, bs, prompt_ids, valid_from, out)
    }

    pub fn ar_step(
        &self,
        bs: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        tok_ids: &TensorI32, // [bs]
        out: &mut ArStepOut,
    ) -> Result<()> {
        self.rt
            .backend()
            .ar_step(self.weights, bs, kv, valid_from, tok_ids, out)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::ProposalLogits;

    #[test]
    fn sparse_logits_round_trip() {
        let mut p = ProposalLogits::default();
        p.reuse(3, 8);
        p.set(0, 5, 5.0);
        p.set(1, 2, 5.0);
        p.set(2, 7, 1.5);
        assert_eq!(p.get(0, 5), 5.0);
        assert_eq!(p.get(0, 4), 0.0);
        assert_eq!(p.peak(2), (7, 1.5));
        let d = p.to_dense();
        assert_eq!(d.shape, vec![3, 8]);
        assert_eq!(d.data[5], 5.0);
        assert_eq!(d.data[8 + 2], 5.0);
        assert_eq!(d.data[2 * 8 + 7], 1.5);
        assert_eq!(d.data.iter().filter(|&&x| x != 0.0).count(), 3);
    }

    #[test]
    fn sparse_logits_reuse_rezeroes_on_geometry_change() {
        let mut p = ProposalLogits::default();
        p.reuse(2, 4);
        p.set(0, 1, 5.0);
        p.set(1, 2, 5.0);
        p.reuse(2, 4); // same geometry: peaks retained
        assert_eq!(p.peak(0), (1, 5.0));
        p.reuse(3, 4); // row change: cleared
        assert_eq!(p.peak(0), (0, 0.0));
        assert_eq!(p.rows(), 3);
    }

    #[test]
    fn dense_reduction_takes_peak_at_proposed_token() {
        let dense = vec![0.0, 0.0, 3.0, 0.0, 1.0, 0.0, 0.0, 0.0];
        let tok = vec![2, 0];
        let mut p = ProposalLogits::default();
        p.set_from_dense(&dense, &tok, 4);
        assert_eq!(p.peak(0), (2, 3.0));
        assert_eq!(p.peak(1), (0, 1.0));
    }
}
