//! Typed wrappers over the AOT program set.
//!
//! `Programs` binds one model's weights to the runtime's backend and
//! exposes the eight program entry points with host-tensor signatures;
//! engines never see backend-specific types. KV caches flow through as
//! borrowed [`KvView`]s (zero-copy slab windows); everything else is a
//! host tensor. Output tuple orders are fixed by the L2 function
//! signatures in `python/compile/model.py`.
#![allow(clippy::too_many_arguments)]

use anyhow::Result;

use super::backend::{Backend, Runtime};
use super::kv::KvView;
use super::tensor::{TensorF32, TensorI32};
use super::weights::ModelWeights;

/// One refinement step over every sequence position (vanilla teacher).
pub struct DenoiseOut {
    pub logits: TensorF32, // [bs, S, V]
    pub tok: TensorI32,    // [bs, S]
    pub conf: TensorF32,   // [bs, S]
}

/// Full step that also returns the KV stacks (approx-cache refresh).
pub struct FullCacheOut {
    pub logits: TensorF32,
    pub tok: TensorI32,
    pub conf: TensorF32,
    pub k: TensorF32, // [L, bs, H, S, dh]
    pub v: TensorF32,
}

/// Block-scoped step (student exact-cache / teacher approx-cache).
pub struct BlockStepOut {
    pub logits: TensorF32, // [bs, B, V]
    pub tok: TensorI32,    // [bs, B]
    pub conf: TensorF32,   // [bs, B]
    pub k_blk: TensorF32,  // [L, bs, H, B, dh]
    pub v_blk: TensorF32,
}

pub struct PrefillOut {
    pub k: TensorF32, // [L, bs, H, P, dh]
    pub v: TensorF32,
}

pub struct ArPrefillOut {
    pub logits: TensorF32, // [bs, V]
    pub tok: TensorI32,    // [bs]
    pub conf: TensorF32,   // [bs]
    pub k: TensorF32,
    pub v: TensorF32,
}

pub struct ArStepOut {
    pub logits: TensorF32, // [bs, V]
    pub tok: TensorI32,
    pub conf: TensorF32,
    pub k1: TensorF32, // [L, bs, H, 1, dh]
    pub v1: TensorF32,
}

/// Program set bound to one model's weights.
pub struct Programs<'rt> {
    pub rt: &'rt Runtime,
    pub weights: &'rt ModelWeights,
}

impl<'rt> Programs<'rt> {
    pub fn new(rt: &'rt Runtime, weights: &'rt ModelWeights) -> Self {
        Self { rt, weights }
    }

    pub fn teacher_denoise(
        &self,
        bs: usize,
        ids: &TensorI32,        // [bs, S]
        valid_from: &TensorI32, // [bs]
    ) -> Result<DenoiseOut> {
        self.rt.backend().teacher_denoise(self.weights, bs, ids, valid_from)
    }

    pub fn teacher_full_cache(
        &self,
        bs: usize,
        ids: &TensorI32,
        valid_from: &TensorI32,
    ) -> Result<FullCacheOut> {
        self.rt
            .backend()
            .teacher_full_cache(self.weights, bs, ids, valid_from)
    }

    pub fn teacher_block_approx(
        &self,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        blk_ids: &TensorI32, // [bs, B]
        pos0: i32,
    ) -> Result<BlockStepOut> {
        self.rt.backend().teacher_block_approx(
            self.weights,
            bs,
            block,
            kv,
            valid_from,
            blk_ids,
            pos0,
        )
    }

    pub fn student_prefill(
        &self,
        bs: usize,
        prompt_ids: &TensorI32, // [bs, P]
        valid_from: &TensorI32,
    ) -> Result<PrefillOut> {
        self.rt
            .backend()
            .student_prefill(self.weights, bs, prompt_ids, valid_from)
    }

    pub fn student_block_step(
        &self,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
    ) -> Result<BlockStepOut> {
        self.rt.backend().student_block_step(
            self.weights,
            bs,
            block,
            kv,
            valid_from,
            blk_ids,
            pos0,
        )
    }

    /// Parallel AR verification of a drafted block (Appendix C
    /// speculative-decoding extension): causal teacher-forcing over the
    /// drafted tokens against the AR cache.
    pub fn ar_verify(
        &self,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
    ) -> Result<BlockStepOut> {
        self.rt.backend().ar_verify(
            self.weights,
            bs,
            block,
            kv,
            valid_from,
            blk_ids,
            pos0,
        )
    }

    pub fn ar_prefill(
        &self,
        bs: usize,
        prompt_ids: &TensorI32,
        valid_from: &TensorI32,
    ) -> Result<ArPrefillOut> {
        self.rt
            .backend()
            .ar_prefill(self.weights, bs, prompt_ids, valid_from)
    }

    pub fn ar_step(
        &self,
        bs: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        tok_ids: &TensorI32, // [bs]
    ) -> Result<ArStepOut> {
        self.rt.backend().ar_step(self.weights, bs, kv, valid_from, tok_ids)
    }
}
