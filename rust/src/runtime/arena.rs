//! Step arena: reusable per-machine scratch for the decode hot path.
//!
//! Every program output (and every padded program input the engines
//! assemble) lives in one [`StepArena`] owned by the decode machine
//! (`BatchState`) or by a closed-batch engine invocation. Buffers are
//! sized on first use (admission / the first step of a batch shape) and
//! reused on every subsequent `step_cycle`: `TensorF32::reuse` keeps
//! the allocation when the shape is unchanged and zero-fills only on a
//! shape change, so steady-state decode steps perform **zero** heap
//! allocations — the property `cdlm bench --scenario hotpath` gates
//! with a counting global allocator.
//!
//! Correctness under reuse rests on the overwrite contract documented
//! in [`crate::runtime::programs`]: for a fixed shape, producers
//! rewrite every element they ever set, so dirty buffers are
//! indistinguishable from fresh ones; `tests/hot_path.rs` pins this by
//! decoding through a deliberately dirty arena across different batch
//! shapes and comparing traces against a fresh machine.

use super::programs::{
    ArPrefillOut, ArStepOut, BlockStepOut, DenoiseOut, FullCacheOut,
    PrefillOut,
};
use super::tensor::TensorI32;

/// Reusable decode-step scratch: one instance per decode machine (or
/// per closed-batch engine call), never shared across threads.
#[derive(Default)]
pub struct StepArena {
    /// `teacher_denoise` output (vanilla / Fast-dLLM parallel).
    pub denoise: DenoiseOut,
    /// `teacher_full_cache` output (dLLM-Cache refresh steps).
    pub full_cache: FullCacheOut,
    /// Block-step output (`student_block_step` / `teacher_block_approx`
    /// / `ar_verify`) — one per arena; engines that need two live block
    /// outputs at once (speculative decoding) use two arenas.
    pub block: BlockStepOut,
    /// `student_prefill` output (admission).
    pub prefill: PrefillOut,
    /// `ar_prefill` output (admission).
    pub ar_prefill: ArPrefillOut,
    /// `ar_step` output.
    pub ar_step: ArStepOut,
    /// Padded full-sequence ids `[pad, S]` (full-seq engines).
    pub ids: TensorI32,
    /// Padded block ids `[pad, B]` (block engines).
    pub blk: TensorI32,
    /// Padded current-token ids `[pad]` (AR engine).
    pub tok: TensorI32,
    /// Padded per-lane valid-from offsets `[pad]`.
    pub valid_from: TensorI32,
}

impl StepArena {
    pub fn new() -> Self {
        Self::default()
    }
}
