//! # CDLM — Consistency Diffusion Language Models for Faster Sampling
//!
//! Rust serving coordinator for the MLSys'26 CDLM paper reproduction:
//! a three-layer stack in which **rust owns the request path** (routing,
//! dynamic batching, exact block KV caching, decode scheduling, metrics,
//! HTTP) and executes model programs through a pluggable [`runtime`]
//! backend — the deterministic pure-Rust reference backend by default,
//! or AOT-compiled JAX/Pallas programs via the PJRT C API with the
//! `pjrt` cargo feature. Python runs once at build time
//! (`make artifacts`) and is never on the request path.
//!
//! Crate map (see rust/README.md for the paper mapping):
//! * [`runtime`] — backend seam, reference backend, PJRT client,
//!   typed program wrappers;
//! * [`coordinator`] — router/batcher/scheduler/KV-pool + the six decode
//!   engines of paper Tables 1-2 (vanilla, dLLM-Cache, Fast-dLLM Par./
//!   +D.C., CDLM, AR);
//! * [`analysis`] — §5.4 arithmetic-intensity + Appendix B.4 roofline
//!   models (reproduce the paper's A100 numbers analytically);
//! * [`workload`] / [`tokenizer`] — synthetic benchmarks + vocab,
//!   golden-pinned mirrors of the python build path;
//! * [`server`] — minimal HTTP front-end;
//! * [`util`] — std-only JSON/CLI/RNG/stats/property-test infrastructure
//!   (the offline registry has no serde/clap/criterion/proptest).

// A panicking worker is survivable (the supervisor catches, quarantines
// and re-dispatches), but that makes every `.unwrap()` on the request
// path a potential availability incident rather than a crash report —
// so unwraps must justify themselves: test code allows the lint at the
// module, invariant-backed sites use `.expect(why)` or a scoped allow.
#![warn(clippy::unwrap_used)]

pub mod analysis;
pub mod bench_support;
pub mod coordinator;
pub mod hotpath;
pub mod runtime;
pub mod server;
pub mod tokenizer;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Locate the artifacts directory: `$CDLM_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("CDLM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// True when artifacts exist (several tests/benches skip gracefully
/// otherwise so `cargo test` works pre-`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
