//! Synthetic benchmark workloads — the rust mirror of
//! `python/compile/tasks.py`.
//!
//! Generators must be byte-identical with python (same SplitMix64 draws
//! in the same order); `artifacts/golden/tasks.json` pins parity in the
//! integration tests. Paper-benchmark mapping (rust/README.md):
//! chain-arith↔GSM8K-CoT, deep-arith↔MATH, str-transform↔HumanEval,
//! list-op↔MBPP.

mod eval_set;
mod gen;
mod prompt;
mod score;

pub use eval_set::EvalSet;
pub use gen::{generate, Family, Sample, FAMILIES};
pub use prompt::{encode_example, few_shot_examples, num_shots, EncodedSample};
pub use score::{extract_final, score};

impl Family {
    pub fn paper_analogue(&self) -> &'static str {
        match self {
            Family::ChainArith => "GSM8K-CoT",
            Family::DeepArith => "MATH",
            Family::StrTransform => "HumanEval",
            Family::ListOp => "MBPP",
        }
    }
}
