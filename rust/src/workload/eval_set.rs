//! Loader for the python-exported eval sets (`artifacts/eval/*.json`).
//!
//! Benches normally regenerate prompts through the mirrored generators;
//! this loader provides the byte-identical exported sets and doubles as
//! a third cross-language pin (generator mirror == exported file).

use std::path::Path;

use anyhow::Result;

use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub struct EvalSet {
    pub family: String,
    pub paper_analogue: String,
    pub num_shots: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub prompts: Vec<Vec<i32>>,       // [n][P] token ids, left-padded
    pub ref_answers: Vec<Vec<i32>>,   // [n][Lg]
    pub finals: Vec<String>,
}

impl EvalSet {
    pub fn load(artifacts: &Path, family: &str) -> Result<EvalSet> {
        let j = json::load(&artifacts.join("eval").join(format!("{family}.json")))?;
        let rows = |key: &str| -> Result<Vec<Vec<i32>>> {
            Ok(j.req(key)?
                .as_arr()
                .unwrap_or_default()
                .iter()
                .filter_map(Json::as_i32_vec)
                .collect())
        };
        let set = EvalSet {
            family: j.req("family")?.as_str().unwrap_or("").to_string(),
            paper_analogue: j
                .req("paper_analogue")?
                .as_str()
                .unwrap_or("")
                .to_string(),
            num_shots: j.req("num_shots")?.as_usize().unwrap_or(0),
            prompt_len: j.req("prompt_len")?.as_usize().unwrap_or(0),
            gen_len: j.req("gen_len")?.as_usize().unwrap_or(0),
            prompts: rows("prompts")?,
            ref_answers: rows("ref_answers")?,
            finals: j
                .req("finals")?
                .as_arr()
                .unwrap_or_default()
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect(),
        };
        anyhow::ensure!(
            set.prompts.len() == set.finals.len()
                && set.prompts.len() == set.ref_answers.len(),
            "eval set {family}: ragged arrays"
        );
        anyhow::ensure!(
            set.prompts.iter().all(|p| p.len() == set.prompt_len),
            "eval set {family}: prompt length mismatch"
        );
        Ok(set)
    }

    pub fn len(&self) -> usize {
        self.prompts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prompts.is_empty()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::tokenizer::Tokenizer;
    use crate::workload;

    #[test]
    fn exported_sets_match_mirrored_generators() {
        let dir = crate::artifacts_dir();
        if !dir.join("eval").join("chain-arith.json").exists() {
            eprintln!("skipping: no eval sets");
            return;
        }
        let tok = Tokenizer::new();
        for fam in workload::FAMILIES {
            let set = EvalSet::load(&dir, fam.name()).unwrap();
            assert!(!set.is_empty());
            // regenerate with the same seed the exporter used
            let samples = workload::generate(fam, set.len(), 0xE7A1);
            for (i, s) in samples.iter().enumerate() {
                assert_eq!(set.finals[i], s.final_answer, "{} row {i}",
                           fam.name());
                let enc = workload::encode_example(
                    &tok, fam, s, set.prompt_len, set.gen_len,
                )
                .unwrap();
                assert_eq!(
                    set.prompts[i], enc.prompt_ids,
                    "{} row {i}: prompt ids drift",
                    fam.name()
                );
                assert_eq!(
                    set.ref_answers[i], enc.ref_answer_ids,
                    "{} row {i}: answer ids drift",
                    fam.name()
                );
            }
        }
    }

    #[test]
    fn missing_family_errors() {
        let dir = crate::artifacts_dir();
        if !dir.join("eval").exists() {
            return;
        }
        assert!(EvalSet::load(&dir, "no-such-family").is_err());
    }
}
