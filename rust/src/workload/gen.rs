//! Task generators. Mirrors `python/compile/tasks.py` draw-for-draw.

use crate::util::rng::SplitMix64;

pub const FAMILIES: [Family; 4] = [
    Family::ChainArith,
    Family::DeepArith,
    Family::StrTransform,
    Family::ListOp,
];

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    ChainArith,
    DeepArith,
    StrTransform,
    ListOp,
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::ChainArith => "chain-arith",
            Family::DeepArith => "deep-arith",
            Family::StrTransform => "str-transform",
            Family::ListOp => "list-op",
        }
    }

    pub fn from_name(s: &str) -> Option<Family> {
        FAMILIES.iter().copied().find(|f| f.name() == s)
    }

    fn seed_xor(&self) -> u64 {
        match self {
            Family::ChainArith => 0x11AA,
            Family::DeepArith => 0x22BB,
            Family::StrTransform => 0x33CC,
            Family::ListOp => 0x44DD,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Sample {
    pub prompt: String,
    pub answer: String,
    pub final_answer: String,
}

const WORDS: [&str; 20] = [
    "cat", "dog", "sun", "map", "key", "box", "fig", "hat", "ink", "jar",
    "kit", "log", "mud", "net", "oak", "pie", "rug", "saw", "tin", "urn",
];

fn gen_chain_arith(rng: &mut SplitMix64) -> Sample {
    let a = rng.below(5) + 1;
    let b = rng.below(5) + 1;
    let c = rng.below(9) + 1;
    if rng.below(2) == 0 {
        let p = a * b;
        let r = p + c;
        Sample {
            prompt: format!("q:{a}*{b}+{c}=?"),
            answer: format!("{a}*{b}={p};{p}+{c}={r};#{r}"),
            final_answer: r.to_string(),
        }
    } else {
        let b2 = rng.below(5) + 1;
        let c2 = rng.below(5) + 1;
        let p = b2 * c2;
        let r = a + p;
        Sample {
            prompt: format!("q:{a}+{b2}*{c2}=?"),
            answer: format!("{b2}*{c2}={p};{a}+{p}={r};#{r}"),
            final_answer: r.to_string(),
        }
    }
}

fn gen_deep_arith(rng: &mut SplitMix64) -> Sample {
    let a = rng.below(6) + 1;
    let b = rng.below(6) + 1;
    let c = rng.below(3) + 2;
    let s1 = a + b;
    let s2 = s1 * c;
    let d = rng.below(s2.min(9)) + 1;
    let s3 = s2 - d;
    Sample {
        prompt: format!("q:(({a}+{b})*{c}-{d})=?"),
        answer: format!("{a}+{b}={s1};{s1}*{c}={s2};{s2}-{d}={s3};#{s3}"),
        final_answer: s3.to_string(),
    }
}

fn gen_str_transform(rng: &mut SplitMix64) -> Sample {
    let w = format!(
        "{}{}",
        WORDS[rng.index(WORDS.len())],
        (b'a' + rng.below(26) as u8) as char
    );
    if rng.below(2) == 0 {
        let out: String = w.chars().rev().collect();
        Sample {
            prompt: format!("q:rev({w})=?"),
            answer: format!("#{out}"),
            final_answer: out,
        }
    } else {
        let out = format!("{w}{w}");
        Sample {
            prompt: format!("q:dup({w})=?"),
            answer: format!("#{out}"),
            final_answer: out,
        }
    }
}

fn gen_list_op(rng: &mut SplitMix64) -> Sample {
    let digits: Vec<u64> = (0..5).map(|_| rng.below(10)).collect();
    let s: String = digits.iter().map(|d| d.to_string()).collect();
    match rng.below(3) {
        0 => {
            let mut ds = digits.clone();
            ds.sort_unstable();
            let out: String = ds.iter().map(|d| d.to_string()).collect();
            Sample {
                prompt: format!("q:sort({s})=?"),
                answer: format!("#{out}"),
                final_answer: out,
            }
        }
        1 => {
            let out = digits.iter().max().expect("digits nonempty").to_string();
            Sample {
                prompt: format!("q:max({s})=?"),
                answer: format!("#{out}"),
                final_answer: out,
            }
        }
        _ => {
            let out = digits.iter().min().expect("digits nonempty").to_string();
            Sample {
                prompt: format!("q:min({s})=?"),
                answer: format!("#{out}"),
                final_answer: out,
            }
        }
    }
}

pub fn generate(family: Family, n: usize, seed: u64) -> Vec<Sample> {
    let mut rng = SplitMix64::new(seed ^ family.seed_xor());
    (0..n)
        .map(|_| match family {
            Family::ChainArith => gen_chain_arith(&mut rng),
            Family::DeepArith => gen_deep_arith(&mut rng),
            Family::StrTransform => gen_str_transform(&mut rng),
            Family::ListOp => gen_list_op(&mut rng),
        })
        .collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn deterministic() {
        for fam in FAMILIES {
            assert_eq!(generate(fam, 8, 7), generate(fam, 8, 7));
        }
    }

    #[test]
    fn chain_arith_cot_is_valid() {
        for s in generate(Family::ChainArith, 64, 3) {
            assert_eq!(
                s.answer.rsplit('#').next().unwrap(),
                s.final_answer
            );
        }
    }

    #[test]
    fn str_transform_semantics() {
        for s in generate(Family::StrTransform, 64, 11) {
            let arg: String = s
                .prompt
                .split('(')
                .nth(1)
                .unwrap()
                .trim_end_matches(")=?")
                .to_string();
            if s.prompt.starts_with("q:rev") {
                assert_eq!(s.final_answer, arg.chars().rev().collect::<String>());
            } else {
                assert_eq!(s.final_answer, format!("{arg}{arg}"));
            }
        }
    }

    #[test]
    fn list_op_semantics_property() {
        check("list-op-correct", 30, |r| {
            let seed = r.next_u64();
            generate(Family::ListOp, 4, seed).iter().all(|s| {
                let arg: String = s
                    .prompt
                    .split('(')
                    .nth(1)
                    .unwrap()
                    .trim_end_matches(")=?")
                    .to_string();
                let mut cs: Vec<char> = arg.chars().collect();
                if s.prompt.contains("sort") {
                    cs.sort_unstable();
                    s.final_answer == cs.iter().collect::<String>()
                } else if s.prompt.contains("max") {
                    s.final_answer
                        == cs.iter().max().unwrap().to_string()
                } else {
                    s.final_answer
                        == cs.iter().min().unwrap().to_string()
                }
            })
        });
    }

    #[test]
    fn family_names_roundtrip() {
        for f in FAMILIES {
            assert_eq!(Family::from_name(f.name()), Some(f));
        }
        assert_eq!(Family::from_name("nope"), None);
    }

    #[test]
    fn deep_arith_stays_nonnegative() {
        check("deep-arith-nonneg", 50, |r| {
            generate(Family::DeepArith, 4, r.next_u64())
                .iter()
                .all(|s| s.final_answer.parse::<i64>().unwrap() >= 0)
        });
    }
}
