//! Few-shot prompt assembly + fixed-geometry encoding.
//! Mirrors `tasks.build_prompt_text` / `tasks.encode_example`.

use super::gen::{generate, Family, Sample};
use crate::tokenizer::{Tokenizer, BOS, EOS, PAD};

/// Few-shot protocol (paper: few-shot math, 0-shot coding).
pub fn num_shots(family: Family) -> usize {
    match family {
        Family::ChainArith | Family::DeepArith => 1,
        Family::StrTransform | Family::ListOp => 0,
    }
}

/// Fixed shots per family, disjoint from eval seeds (python seed 0xF00D).
pub fn few_shot_examples(family: Family) -> Vec<Sample> {
    let k = num_shots(family);
    if k == 0 {
        vec![]
    } else {
        generate(family, k, 0xF00D)
    }
}

#[derive(Debug, Clone)]
pub struct EncodedSample {
    pub prompt_ids: Vec<i32>,  // left-padded to prompt_len
    pub ref_answer_ids: Vec<i32>,
    pub sample: Sample,
}

fn build_prompt_text(sample: &Sample, shots: &[Sample]) -> String {
    let mut s = String::new();
    for sh in shots {
        s.push_str(&format!("{}a:{};", sh.prompt, sh.answer));
    }
    s.push_str(&format!("{}a:", sample.prompt));
    s
}

/// Tokenize a sample to the fixed geometry: `[<pad>…, <bos>, prompt]` and
/// `[answer…, <eos>, <pad>…]`.
pub fn encode_example(
    tok: &Tokenizer,
    family: Family,
    sample: &Sample,
    prompt_len: usize,
    gen_len: usize,
) -> anyhow::Result<EncodedSample> {
    let shots = few_shot_examples(family);
    let ptext = build_prompt_text(sample, &shots);
    let mut pids = vec![BOS];
    pids.extend(tok.encode(&ptext)?);
    anyhow::ensure!(
        pids.len() <= prompt_len,
        "prompt too long ({} > {prompt_len}): {ptext:?}",
        pids.len()
    );
    let mut prompt_ids = vec![PAD; prompt_len - pids.len()];
    prompt_ids.extend(pids);

    let mut aids = tok.encode(&format!("{};", sample.answer))?;
    aids.push(EOS);
    anyhow::ensure!(
        aids.len() <= gen_len,
        "answer too long ({} > {gen_len})",
        aids.len()
    );
    // EOS-padded tail (mirrors python: every position supervised)
    aids.resize(gen_len, EOS);
    Ok(EncodedSample { prompt_ids, ref_answer_ids: aids, sample: sample.clone() })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn encoding_geometry() {
        let tok = Tokenizer::new();
        let s = generate(Family::ListOp, 1, 1)[0].clone();
        let e = encode_example(&tok, Family::ListOp, &s, 64, 32).unwrap();
        assert_eq!(e.prompt_ids.len(), 64);
        assert_eq!(e.ref_answer_ids.len(), 32);
        assert!(e.ref_answer_ids.contains(&EOS));
    }

    #[test]
    fn left_padding_then_bos() {
        let tok = Tokenizer::new();
        let s = generate(Family::ListOp, 1, 1)[0].clone();
        let e = encode_example(&tok, Family::ListOp, &s, 64, 32).unwrap();
        let first = e.prompt_ids.iter().position(|&t| t != PAD).unwrap();
        assert_eq!(e.prompt_ids[first], BOS);
        assert!(e.prompt_ids[..first].iter().all(|&t| t == PAD));
    }

    #[test]
    fn few_shot_counts_match_protocol() {
        assert_eq!(few_shot_examples(Family::ChainArith).len(), 1);
        assert_eq!(few_shot_examples(Family::StrTransform).len(), 0);
    }

    #[test]
    fn shots_are_stable() {
        assert_eq!(
            few_shot_examples(Family::ChainArith),
            few_shot_examples(Family::ChainArith)
        );
    }

    #[test]
    fn one_shot_prompt_contains_shot_answer() {
        let tok = Tokenizer::new();
        let s = generate(Family::ChainArith, 1, 2)[0].clone();
        let e = encode_example(&tok, Family::ChainArith, &s, 64, 32).unwrap();
        let text = tok.decode(&e.prompt_ids, false);
        assert!(text.contains('#'), "shot CoT must appear: {text}");
        assert!(text.ends_with("a:"));
    }

    #[test]
    fn all_eval_samples_fit() {
        let tok = Tokenizer::new();
        for fam in super::super::FAMILIES {
            for s in generate(fam, 128, 0xE7A1) {
                encode_example(&tok, fam, &s, 64, 32).unwrap();
            }
        }
    }
}
