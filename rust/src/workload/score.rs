//! Scoring: the lm-eval-harness-style protocol (paper §A.3) —
//! truncate at stop sequences, extract the final answer after the last
//! '#', exact-match against the reference. Mirrors `tasks.extract_final`
//! / `tasks.score`.

use super::gen::Sample;

/// Text after the last '#', truncated at ';'. None if no '#' was emitted.
pub fn extract_final(text: &str) -> Option<&str> {
    let tail = text.rsplit_once('#')?.1;
    Some(tail.split(';').next().unwrap_or(tail))
}

pub fn score(generated_text: &str, sample: &Sample) -> bool {
    extract_final(generated_text) == Some(sample.final_answer.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(final_answer: &str) -> Sample {
        Sample {
            prompt: "q".into(),
            answer: "a".into(),
            final_answer: final_answer.into(),
        }
    }

    #[test]
    fn extracts_after_last_hash() {
        assert_eq!(extract_final("3*4=12;#17;"), Some("17"));
        assert_eq!(extract_final("#1;x#2;"), Some("2"));
        assert_eq!(extract_final("no hash"), None);
        assert_eq!(extract_final("#tail-no-semicolon"), Some("tail-no-semicolon"));
    }

    #[test]
    fn scoring() {
        assert!(score("cot;#17;", &sample("17")));
        assert!(!score("cot;#18;", &sample("17")));
        assert!(!score("17", &sample("17")));
        assert!(score("x#17;trailing", &sample("17")));
    }

    #[test]
    fn empty_final() {
        assert_eq!(extract_final("#;"), Some(""));
        assert!(!score("#;", &sample("17")));
    }
}
