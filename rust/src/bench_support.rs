//! Shared harness for the paper-table benches (criterion is unavailable
//! offline; bench targets use `harness = false` and this module).
//!
//! Every bench regenerates one table or figure from the paper's
//! evaluation section: same rows, same columns, with speedup ratios
//! relative to the naive baseline as the paper prints them. Absolute
//! numbers differ (tiny backbone, CPU execution) — the *shape* (who
//! wins, by roughly what factor) is the reproduction target.

use anyhow::Result;

use crate::coordinator::{
    DecodeOpts, GroupKey, Method, MetricsAggregator, RequestRecord,
    ServingCore,
};
use crate::workload::{self, Family};

/// Eval-set size: benches default small on this 1-core box; override
/// with CDLM_EVAL_N.
pub fn eval_n(default: usize) -> usize {
    std::env::var("CDLM_EVAL_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Decode batch size for grid runs (1 matches the paper's measurement
/// protocol: batch size 1 per GPU, §A.3). Override with CDLM_BENCH_BS.
pub fn bench_bs() -> usize {
    std::env::var("CDLM_BENCH_BS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

#[derive(Debug, Clone)]
pub struct Row {
    pub family: Family,
    pub method: Method,
    pub tps: f64,
    pub latency_s: f64,
    pub steps: f64,
    pub model_calls: f64,
    pub gen_len: f64,
    pub score: f64,
}

/// Run one (family, method) cell: decode `n` eval prompts in
/// `bench_bs()`-sized groups, score, aggregate per-sample (§A.3).
pub fn run_cell(
    core: &mut ServingCore,
    backbone: &str,
    method: Method,
    family: Family,
    n: usize,
    opts: &DecodeOpts,
) -> Result<Row> {
    let geom = core.rt.manifest.geometry.clone();
    let samples = workload::generate(family, n, 0xE7A1);
    let enc: Vec<_> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                family,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
        })
        .collect::<Result<Vec<_>>>()?;
    let key = GroupKey::new(backbone, method);
    let bs = bench_bs();
    let mut agg = MetricsAggregator::new();
    // warm-up: compile the programs outside the timed region
    let warm: Vec<Vec<i32>> = vec![enc[0].prompt_ids.clone()];
    core.decode_group(&key, &warm, opts)?;
    for (chunk_enc, chunk_samples) in
        enc.chunks(bs).zip(samples.chunks(bs))
    {
        let prompts: Vec<Vec<i32>> =
            chunk_enc.iter().map(|e| e.prompt_ids.clone()).collect();
        let outs = core.decode_group(&key, &prompts, opts)?;
        for (o, s) in outs.iter().zip(chunk_samples) {
            let text = core.tokenizer.decode(&o.gen, true);
            agg.record(&RequestRecord {
                latency: o.latency,
                steps: o.steps,
                model_calls: o.model_calls,
                gen_len: o.gen_len,
                correct: Some(workload::score(&text, s)),
            });
        }
    }
    Ok(Row {
        family,
        method,
        tps: agg.tps(),
        latency_s: agg.avg_latency_s(),
        steps: agg.avg_steps(),
        model_calls: agg.avg_model_calls(),
        gen_len: agg.avg_gen_len(),
        score: agg.score(),
    })
}

/// Print rows in the paper's Table 1/2 format, with (xN) speedups
/// relative to the `baseline` method within each family.
pub fn print_paper_table(
    title: &str,
    backbone: &str,
    rows: &[Row],
    baseline: Method,
) {
    println!("\n=== {title} ===");
    println!(
        "{:<14} {:<24} {:>16} {:>18} {:>16} {:>10} {:>7}",
        "Benchmark", "Method", "TPS^", "Latency(s)v", "Steps v", "Gen.Len",
        "Score^"
    );
    let mut fam_seen: Vec<Family> = Vec::new();
    for r in rows {
        if !fam_seen.contains(&r.family) {
            fam_seen.push(r.family);
        }
    }
    for fam in fam_seen {
        let base = rows
            .iter()
            .find(|r| r.family == fam && r.method == baseline)
            .cloned();
        for r in rows.iter().filter(|r| r.family == fam) {
            let (tps_x, lat_x, steps_x) = match &base {
                Some(b) if b.tps > 0.0 => (
                    r.tps / b.tps,
                    b.latency_s / r.latency_s.max(1e-9),
                    b.steps / r.steps.max(1e-9),
                ),
                _ => (1.0, 1.0, 1.0),
            };
            println!(
                "{:<14} {:<24} {:>8.1} (x{:<4.1}) {:>9.2} (x{:<4.1}) {:>8.1} (x{:<3.1}) {:>10.1} {:>7.1}",
                format!("{} [{}]", r.family.name(),
                        r.family.paper_analogue()),
                r.method.paper_label(backbone),
                r.tps,
                tps_x,
                r.latency_s,
                lat_x,
                r.steps,
                steps_x,
                r.gen_len,
                r.score,
            );
        }
    }
}

/// Emit machine-readable results next to the human table (consumed by
/// EXPERIMENTS.md tooling and regression diffing).
pub fn rows_to_json(rows: &[Row]) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::arr(rows.iter().map(|r| {
        Json::obj(vec![
            ("family", Json::str(r.family.name())),
            ("method", Json::str(r.method.name())),
            ("tps", Json::num(r.tps)),
            ("latency_s", Json::num(r.latency_s)),
            ("steps", Json::num(r.steps)),
            ("model_calls", Json::num(r.model_calls)),
            ("gen_len", Json::num(r.gen_len)),
            ("score", Json::num(r.score)),
        ])
    }))
}

/// Standard bench preamble. With AOT artifacts present the measured
/// backend serves them; without, the deterministic reference backend
/// stands in so `cargo bench` runs hermetically on a fresh checkout.
pub fn require_artifacts(bench: &str) -> Option<ServingCore> {
    match ServingCore::load(&crate::artifacts_dir(), 32) {
        Ok(c) => {
            // always announce the measured backend: reference-backend
            // numbers must never masquerade as PJRT measurements
            eprintln!(
                "[{bench}] backend: {} (platform {})",
                c.rt.backend_name(),
                c.rt.platform()
            );
            Some(c)
        }
        Err(e) => {
            eprintln!("[{bench}] failed to load serving core: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Write bench JSON under artifacts/bench_results/.
pub fn save_results(name: &str, j: crate::util::json::Json) {
    let dir = crate::artifacts_dir().join("bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{name}.json"));
    if std::fs::write(&path, j.to_string()).is_ok() {
        eprintln!("[{name}] results -> {}", path.display());
    }
}

/// One request's fully drained event stream, audited against the
/// lane-event contract (`Admitted?` · `Committed*` · exactly one
/// terminal). The chaos bench and the fault-tolerance tests both gate
/// on `terminals == 1` for every admitted request, fault or no fault.
#[derive(Debug)]
pub struct TerminalAudit {
    /// Set when the terminal was `Finished`.
    pub finished: Option<crate::coordinator::GenerateResponse>,
    /// Set when the terminal was `Aborted`.
    pub abort_reason: Option<String>,
    /// Terminal events observed — the contract demands exactly one.
    pub terminals: usize,
    /// `Committed` block deltas observed before the terminal.
    pub committed_blocks: usize,
}

/// Drain a response stream to channel close, counting terminals rather
/// than stopping at the first one — a duplicated terminal (the bug
/// class supervision re-dispatch could introduce) must surface as
/// `terminals == 2`, not be silently swallowed.
pub fn drain_and_audit(
    handle: &crate::coordinator::ResponseHandle,
) -> TerminalAudit {
    use crate::coordinator::LaneEvent;
    let mut audit = TerminalAudit {
        finished: None,
        abort_reason: None,
        terminals: 0,
        committed_blocks: 0,
    };
    while let Some(ev) = handle.next_event() {
        match ev {
            LaneEvent::Admitted => {}
            LaneEvent::Committed { .. } => audit.committed_blocks += 1,
            LaneEvent::Finished(resp) => {
                audit.terminals += 1;
                audit.finished = Some(resp);
            }
            LaneEvent::Aborted { reason, .. } => {
                audit.terminals += 1;
                audit.abort_reason = Some(reason);
            }
        }
    }
    audit
}

/// The per-cell fields of a `cdlm.bench.decode/v1` document that are
/// exact deterministic integers on the reference backend — the CI
/// accounting gate compares these and nothing else (throughput and
/// latency stay unasserted; shared runners are too noisy).
const ACCOUNTING_FIELDS: [&str; 4] =
    ["requests", "tokens", "total_steps", "total_model_calls"];

/// Cell identity: (method, batch, cancel_at_block, routed, preempt).
/// Full-decode cells have no `cancel_at_block` field and key as
/// `u64::MAX`; the cancelled-lane cells key by the block cycle the
/// cancellation fired at, so the same (method, batch) can carry both
/// cell kinds. `routed` (0/1) separates the sharded-router solo-cohort
/// cells from the direct batch-1 cells: their accounting is identical
/// by construction, and keying them apart is what lets the CI replica
/// matrix gate the routed numbers without touching the direct ones.
/// `preempt` (0/1) likewise separates the suspend/spill/resume cells —
/// whose accounting must equal the uninterrupted run of the same
/// (method, batch) — from that uninterrupted run itself.
fn cell_key(
    cell: &crate::util::json::Json,
) -> Option<(String, u64, u64, u64, u64)> {
    let m = cell.get("method")?.as_str()?.to_string();
    let b = cell.get("batch")?.as_f64()?;
    let c = cell
        .get("cancel_at_block")
        .and_then(crate::util::json::Json::as_f64)
        .map(|v| v as u64)
        .unwrap_or(u64::MAX);
    let r = cell
        .get("routed")
        .and_then(crate::util::json::Json::as_f64)
        .map(|v| v as u64)
        .unwrap_or(0);
    let p = cell
        .get("preempt")
        .and_then(crate::util::json::Json::as_f64)
        .map(|v| v as u64)
        .unwrap_or(0);
    Some((m, b as u64, c, r, p))
}

/// Human label for drift reports.
fn cell_label(key: &(String, u64, u64, u64, u64)) -> String {
    let routed = if key.3 != 0 { "/routed" } else { "" };
    let preempt = if key.4 != 0 { "/preempt" } else { "" };
    if key.2 == u64::MAX {
        format!("{}/bs{}{routed}{preempt}", key.0, key.1)
    } else {
        format!("{}/bs{}/cancel@{}{routed}{preempt}", key.0, key.1, key.2)
    }
}

/// Compare a freshly measured `cdlm.bench.decode/v1` document against
/// the committed accounting baseline: every baseline cell must exist
/// with identical step/model-call accounting, and no cells may appear
/// or vanish. Returns a newline-separated drift report on mismatch —
/// any drift is a hard CI failure (an intentional accounting change
/// regenerates the baseline in the same PR).
pub fn check_baseline(
    current: &crate::util::json::Json,
    baseline: &crate::util::json::Json,
) -> Result<(), String> {
    use crate::util::json::Json;
    let cur = current
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| "current document has no results array".to_string())?;
    let base = baseline
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| "baseline document has no results array".to_string())?;
    let mut drifts = Vec::new();
    if cur.len() != base.len() {
        drifts.push(format!(
            "result cell count changed: {} (baseline {})",
            cur.len(),
            base.len()
        ));
    }
    for bc in base {
        let Some(key) = cell_key(bc) else {
            return Err("baseline cell lacks method/batch".to_string());
        };
        let Some(cc) = cur.iter().find(|c| cell_key(c).as_ref() == Some(&key))
        else {
            drifts.push(format!(
                "cell {} missing from the current run",
                cell_label(&key)
            ));
            continue;
        };
        for f in ACCOUNTING_FIELDS {
            let bv = bc.get(f).and_then(Json::as_f64);
            let cv = cc.get(f).and_then(Json::as_f64);
            if bv != cv {
                drifts.push(format!(
                    "{}: {f} = {cv:?}, baseline {bv:?}",
                    cell_label(&key)
                ));
            }
        }
    }
    if drifts.is_empty() {
        Ok(())
    } else {
        Err(drifts.join("\n"))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::check_baseline;
    use crate::util::json::Json;

    fn cell(method: &str, batch: f64, calls: f64) -> Json {
        Json::obj(vec![
            ("method", Json::str(method)),
            ("batch", Json::num(batch)),
            ("requests", Json::num(8.0)),
            ("tokens", Json::num(100.0)),
            ("total_steps", Json::num(200.0)),
            ("total_model_calls", Json::num(calls)),
            // noisy fields must never participate in the comparison
            ("tokens_per_s", Json::num(batch * 7.0)),
        ])
    }

    fn doc(cells: Vec<Json>) -> Json {
        Json::obj(vec![
            ("schema", Json::str("cdlm.bench.decode/v1")),
            ("results", Json::Arr(cells)),
        ])
    }

    #[test]
    fn identical_accounting_passes() {
        let a = doc(vec![cell("cdlm", 1.0, 42.0), cell("ar", 4.0, 50.0)]);
        let b = doc(vec![cell("cdlm", 1.0, 42.0), cell("ar", 4.0, 50.0)]);
        assert!(check_baseline(&a, &b).is_ok());
    }

    #[test]
    fn latency_noise_is_ignored() {
        let a = doc(vec![cell("cdlm", 1.0, 42.0)]);
        let mut noisy = cell("cdlm", 1.0, 42.0);
        if let Json::Obj(ref mut m) = noisy {
            m.insert("tokens_per_s".into(), Json::num(9999.0));
            m.insert("p95_latency_ms".into(), Json::num(123.0));
        }
        let b = doc(vec![noisy]);
        assert!(check_baseline(&b, &a).is_ok());
    }

    #[test]
    fn injected_drift_fails_with_the_field_named() {
        let base = doc(vec![cell("cdlm", 1.0, 42.0)]);
        let drifted = doc(vec![cell("cdlm", 1.0, 43.0)]);
        let err = check_baseline(&drifted, &base).unwrap_err();
        assert!(err.contains("total_model_calls"), "{err}");
        assert!(err.contains("cdlm/bs1"), "{err}");
    }

    #[test]
    fn missing_and_extra_cells_fail() {
        let base = doc(vec![cell("cdlm", 1.0, 42.0), cell("ar", 1.0, 9.0)]);
        let cur = doc(vec![cell("cdlm", 1.0, 42.0)]);
        let err = check_baseline(&cur, &base).unwrap_err();
        assert!(err.contains("missing"), "{err}");
        let err = check_baseline(&base, &cur).unwrap_err();
        assert!(err.contains("cell count"), "{err}");
    }

    #[test]
    fn cancel_cells_key_separately_from_full_cells() {
        // a cancelled-lane cell shares (method, batch) with a full cell
        // but must be gated independently
        let cancel = |calls: f64| {
            let mut c = cell("cdlm", 1.0, calls);
            if let Json::Obj(ref mut m) = c {
                m.insert("cancel_at_block".into(), Json::num(2.0));
            }
            c
        };
        let base = doc(vec![cell("cdlm", 1.0, 42.0), cancel(10.0)]);
        let same = doc(vec![cell("cdlm", 1.0, 42.0), cancel(10.0)]);
        assert!(check_baseline(&same, &base).is_ok());
        let drifted = doc(vec![cell("cdlm", 1.0, 42.0), cancel(11.0)]);
        let err = check_baseline(&drifted, &base).unwrap_err();
        assert!(err.contains("cancel@2"), "{err}");
        assert!(!err.contains("cdlm/bs1:"), "full cell must not drift: {err}");
    }

    #[test]
    fn preempt_cells_key_separately_from_uninterrupted_cells() {
        // a suspend/spill/resume cell shares (method, batch) with the
        // uninterrupted cell it must match — the gate keys them apart
        // so a drift names the preempt cell, not the uninterrupted one
        let preempt = |calls: f64| {
            let mut c = cell("cdlm", 4.0, calls);
            if let Json::Obj(ref mut m) = c {
                m.insert("preempt".into(), Json::num(1.0));
            }
            c
        };
        let base = doc(vec![cell("cdlm", 4.0, 42.0), preempt(42.0)]);
        let same = doc(vec![cell("cdlm", 4.0, 42.0), preempt(42.0)]);
        assert!(check_baseline(&same, &base).is_ok());
        let drifted = doc(vec![cell("cdlm", 4.0, 42.0), preempt(43.0)]);
        let err = check_baseline(&drifted, &base).unwrap_err();
        assert!(err.contains("cdlm/bs4/preempt"), "{err}");
        assert!(
            !err.contains("cdlm/bs4:"),
            "uninterrupted cell must not drift: {err}"
        );
    }

    #[test]
    fn routed_cells_key_separately_from_direct_cells() {
        // a router-driven solo-cohort cell shares (method, batch) with
        // the direct batch-1 cell but is gated independently — a drift
        // in the routed path must name the routed cell, not the direct
        // one
        let routed = |calls: f64| {
            let mut c = cell("cdlm", 1.0, calls);
            if let Json::Obj(ref mut m) = c {
                m.insert("routed".into(), Json::num(1.0));
            }
            c
        };
        let base = doc(vec![cell("cdlm", 1.0, 42.0), routed(42.0)]);
        let same = doc(vec![cell("cdlm", 1.0, 42.0), routed(42.0)]);
        assert!(check_baseline(&same, &base).is_ok());
        let drifted = doc(vec![cell("cdlm", 1.0, 42.0), routed(43.0)]);
        let err = check_baseline(&drifted, &base).unwrap_err();
        assert!(err.contains("cdlm/bs1/routed"), "{err}");
    }
}
