//! SIMD-dispatched memory kernels for the KV hot path.
//!
//! Every slab walk in the stack — context fan-out across layers,
//! paged-KV span/page commits, suspend/resume spills, arena zero-fills,
//! and the batch-major widening at the pjrt seam — funnels through this
//! module instead of open-coded scalar loops. The kernels are
//! fixed-width f32x8 primitives on stable Rust: an unrolled
//! `core::arch` intrinsic path selected once at runtime
//! (`is_x86_feature_detected!("avx2")` on x86_64, NEON on aarch64) and
//! a portable unrolled-scalar fallback everywhere else.
//!
//! Dispatch rules:
//! - The ISA is detected once per process and cached in a `OnceLock`;
//!   every public entry point reads the cached value, so steady-state
//!   calls never touch the environment or CPUID again (and never
//!   allocate — the hot-path allocation gate covers these kernels).
//! - `CDLM_FORCE_SCALAR=1` (any non-empty value other than `0`) pins
//!   the scalar fallback for debugging and for the CI leg that keeps
//!   the fallback from bit-rotting on AVX2-capable runners.
//! - Tests that need both paths in one process use the `*_with`
//!   variants, which take an explicit [`Isa`] instead of the cached
//!   one. Requesting an ISA the CPU lacks falls back to scalar.
//!
//! Alignment/tail contract: no kernel requires aligned inputs — the
//! vector paths use unaligned loads/stores (`loadu`/`storeu`,
//! `vld1q`/`vst1q`) so callers may pass any sub-slice offset. Lengths
//! need not be multiples of the vector width; tails shorter than one
//! vector are handled element-wise. Every kernel writes exactly the
//! bytes the equivalent scalar loop writes — byte-for-byte, in every
//! ISA — which is what keeps decode traces identical across machines
//! and is pinned by `tests/simd_kernels.rs`.
//!
//! Cache blocking: multi-row walks (layer fan-out, 2-D strided copies)
//! move one L1-sized chunk of the source row across all destination
//! rows before advancing, so the source chunk is read from L1 `rows`
//! times instead of streaming the full row per destination.

#![allow(clippy::too_many_arguments)]

use std::sync::OnceLock;

/// Environment variable that pins the scalar fallback when set to any
/// non-empty value other than `0`.
pub const FORCE_SCALAR_ENV: &str = "CDLM_FORCE_SCALAR";

/// Elements per cache-blocked chunk for multi-row walks: 2048 f32 =
/// 8 KiB, a quarter of a typical 32 KiB L1D, leaving room for the
/// destination lines of the row being fanned.
const BLOCK_ELEMS: usize = 2048;

/// Instruction-set path a kernel call executes on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// 8-lane f32 AVX2 path (x86_64 only).
    Avx2,
    /// 4-lane f32 NEON path (aarch64 only).
    Neon,
    /// Portable unrolled-scalar fallback.
    Scalar,
}

impl Isa {
    /// Stable label used in bench artifacts and logs.
    pub fn label(self) -> &'static str {
        match self {
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
            Isa::Scalar => "scalar",
        }
    }
}

static ACTIVE: OnceLock<Isa> = OnceLock::new();

fn force_scalar_from_env() -> bool {
    match std::env::var_os(FORCE_SCALAR_ENV) {
        Some(v) => !v.is_empty() && v != "0",
        None => false,
    }
}

/// Pure detection given the env override — split out so the policy is
/// unit-testable without mutating process environment.
fn detect(force_scalar: bool) -> Isa {
    if force_scalar {
        return Isa::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Isa::Neon;
        }
    }
    Isa::Scalar
}

/// The ISA every dispatched kernel call runs on, detected once per
/// process (honoring `CDLM_FORCE_SCALAR`) and cached.
pub fn active_isa() -> Isa {
    *ACTIVE.get_or_init(|| detect(force_scalar_from_env()))
}

/// Clamp a requested ISA to what this CPU can actually execute, so the
/// explicit `*_with` test entry points are safe to call with any
/// variant on any machine.
fn usable(isa: Isa) -> Isa {
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 && std::arch::is_x86_feature_detected!("avx2") {
        return Isa::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    if isa == Isa::Neon && std::arch::is_aarch64_feature_detected!("neon") {
        return Isa::Neon;
    }
    let _ = isa;
    Isa::Scalar
}

// ---------------------------------------------------------------------------
// copy: blocked contiguous copy (dst and src must not overlap)
// ---------------------------------------------------------------------------

/// Copy `src` into `dst` (equal lengths) on the dispatched ISA path.
pub fn copy(dst: &mut [f32], src: &[f32]) {
    copy_with(active_isa(), dst, src);
}

/// [`copy`] with an explicit ISA (parity tests).
pub fn copy_with(isa: Isa, dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "kernels::copy length mismatch");
    match usable(isa) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified AVX2 is available on this CPU.
        Isa::Avx2 => unsafe { copy_avx2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `usable` verified NEON is available on this CPU.
        Isa::Neon => unsafe { copy_neon(dst, src) },
        _ => copy_scalar(dst, src),
    }
}

fn copy_scalar(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = *s;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn copy_avx2(dst: &mut [f32], src: &[f32]) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let (sp, dp) = (src.as_ptr(), dst.as_mut_ptr());
    let mut i = 0;
    // 4x-unrolled 8-lane body, then single vectors, then scalar tail.
    // SAFETY: every offset below is < n, checked by the loop bounds;
    // loads/stores are the unaligned variants.
    unsafe {
        while i + 32 <= n {
            let a = _mm256_loadu_ps(sp.add(i));
            let b = _mm256_loadu_ps(sp.add(i + 8));
            let c = _mm256_loadu_ps(sp.add(i + 16));
            let d = _mm256_loadu_ps(sp.add(i + 24));
            _mm256_storeu_ps(dp.add(i), a);
            _mm256_storeu_ps(dp.add(i + 8), b);
            _mm256_storeu_ps(dp.add(i + 16), c);
            _mm256_storeu_ps(dp.add(i + 24), d);
            i += 32;
        }
        while i + 8 <= n {
            _mm256_storeu_ps(dp.add(i), _mm256_loadu_ps(sp.add(i)));
            i += 8;
        }
        while i < n {
            *dp.add(i) = *sp.add(i);
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn copy_neon(dst: &mut [f32], src: &[f32]) {
    use std::arch::aarch64::*;
    let n = dst.len();
    let (sp, dp) = (src.as_ptr(), dst.as_mut_ptr());
    let mut i = 0;
    // two q-registers per iteration = one f32x8 chunk
    // SAFETY: every offset below is < n, checked by the loop bounds.
    unsafe {
        while i + 8 <= n {
            let a = vld1q_f32(sp.add(i));
            let b = vld1q_f32(sp.add(i + 4));
            vst1q_f32(dp.add(i), a);
            vst1q_f32(dp.add(i + 4), b);
            i += 8;
        }
        while i < n {
            *dp.add(i) = *sp.add(i);
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// fill: broadcast-splat / zero-or-const fill
// ---------------------------------------------------------------------------

/// Fill `dst` with `value` on the dispatched ISA path.
pub fn fill(dst: &mut [f32], value: f32) {
    fill_with(active_isa(), dst, value);
}

/// [`fill`] with an explicit ISA (parity tests).
pub fn fill_with(isa: Isa, dst: &mut [f32], value: f32) {
    match usable(isa) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `usable` verified AVX2 is available on this CPU.
        Isa::Avx2 => unsafe { fill_avx2(dst, value) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `usable` verified NEON is available on this CPU.
        Isa::Neon => unsafe { fill_neon(dst, value) },
        _ => fill_scalar(dst, value),
    }
}

fn fill_scalar(dst: &mut [f32], value: f32) {
    for d in dst.iter_mut() {
        *d = value;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn fill_avx2(dst: &mut [f32], value: f32) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let dp = dst.as_mut_ptr();
    let mut i = 0;
    // SAFETY: offsets bounded by n; unaligned stores.
    unsafe {
        let v = _mm256_set1_ps(value);
        while i + 32 <= n {
            _mm256_storeu_ps(dp.add(i), v);
            _mm256_storeu_ps(dp.add(i + 8), v);
            _mm256_storeu_ps(dp.add(i + 16), v);
            _mm256_storeu_ps(dp.add(i + 24), v);
            i += 32;
        }
        while i + 8 <= n {
            _mm256_storeu_ps(dp.add(i), v);
            i += 8;
        }
        while i < n {
            *dp.add(i) = value;
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn fill_neon(dst: &mut [f32], value: f32) {
    use std::arch::aarch64::*;
    let n = dst.len();
    let dp = dst.as_mut_ptr();
    let mut i = 0;
    // SAFETY: offsets bounded by n.
    unsafe {
        let v = vdupq_n_f32(value);
        while i + 8 <= n {
            vst1q_f32(dp.add(i), v);
            vst1q_f32(dp.add(i + 4), v);
            i += 8;
        }
        while i < n {
            *dp.add(i) = value;
            i += 1;
        }
    }
}

/// Fill an i32 slice with `value` on the dispatched ISA path (arena
/// index/mask buffers share the hot path with the f32 slabs).
pub fn fill_i32(dst: &mut [i32], value: i32) {
    fill_i32_with(active_isa(), dst, value);
}

/// [`fill_i32`] with an explicit ISA (parity tests).
pub fn fill_i32_with(isa: Isa, dst: &mut [i32], value: i32) {
    #[cfg(target_arch = "x86_64")]
    if usable(isa) == Isa::Avx2 {
        // SAFETY: `usable` verified AVX2 is available on this CPU.
        unsafe { fill_i32_avx2(dst, value) };
        return;
    }
    let _ = isa;
    for d in dst.iter_mut() {
        *d = value;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn fill_i32_avx2(dst: &mut [i32], value: i32) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let dp = dst.as_mut_ptr();
    let mut i = 0;
    // SAFETY: offsets bounded by n; unaligned integer stores.
    unsafe {
        let v = _mm256_set1_epi32(value);
        while i + 8 <= n {
            _mm256_storeu_si256(dp.add(i).cast(), v);
            i += 8;
        }
        while i < n {
            *dp.add(i) = value;
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// copy_2d: uniform-stride row copy (the [L,H,S,dh] slab-walk workhorse)
// ---------------------------------------------------------------------------

/// Copy `rows` runs of `run` contiguous f32s from `src` to `dst`, with
/// uniform per-row strides. This is the slab-walk primitive: a
/// [L,H,S,dh] traversal with uniform strides over any two of the axes
/// collapses to one `copy_2d` call per remaining axis, so commits,
/// page writes, and the pjrt-seam widening all move whole `run`-sized
/// lines instead of recomputing a 4-deep index per element.
pub fn copy_2d(
    dst: &mut [f32],
    dst_off: usize,
    dst_stride: usize,
    src: &[f32],
    src_off: usize,
    src_stride: usize,
    rows: usize,
    run: usize,
) {
    copy_2d_with(
        active_isa(),
        dst,
        dst_off,
        dst_stride,
        src,
        src_off,
        src_stride,
        rows,
        run,
    );
}

/// [`copy_2d`] with an explicit ISA (parity tests).
pub fn copy_2d_with(
    isa: Isa,
    dst: &mut [f32],
    dst_off: usize,
    dst_stride: usize,
    src: &[f32],
    src_off: usize,
    src_stride: usize,
    rows: usize,
    run: usize,
) {
    let isa = usable(isa);
    for r in 0..rows {
        let s = src_off + r * src_stride;
        let d = dst_off + r * dst_stride;
        copy_with(isa, &mut dst[d..d + run], &src[s..s + run]);
    }
}

// ---------------------------------------------------------------------------
// fanout_rows: cache-blocked context fan-out across layers
// ---------------------------------------------------------------------------

/// Fan one lane's layer-0 context row across every layer of both KV
/// slabs: `v`'s rows (all `l_n` layers, including layer 0) become
/// copies of `k`'s layer-0 row `k[base .. base+row]`, and `k`'s layers
/// `1..l_n` become copies of its own layer 0. Layer `l`'s row starts
/// at `base + l*lstride`.
///
/// This replaces the per-position `lstride`-strided single-element
/// scatter in `replicate_ctx`: the row is walked in L1-sized chunks,
/// each chunk fanned across all destination layers before advancing
/// (see module docs), so every transfer is a contiguous `run` instead
/// of isolated elements 1.5 cache lines apart. Byte-identity with the
/// scalar scatter holds because producers only ever write the (head 0,
/// feature 0) context positions of these rows and the remaining
/// elements are zero in both source and destination (zeroed at arena
/// reuse, never dirtied) — copying the full row moves exactly the
/// bytes the scatter wrote plus zeros onto zeros.
pub fn fanout_rows(
    k: &mut [f32],
    v: &mut [f32],
    base: usize,
    row: usize,
    l_n: usize,
    lstride: usize,
) {
    fanout_rows_with(active_isa(), k, v, base, row, l_n, lstride);
}

/// [`fanout_rows`] with an explicit ISA (parity tests).
pub fn fanout_rows_with(
    isa: Isa,
    k: &mut [f32],
    v: &mut [f32],
    base: usize,
    row: usize,
    l_n: usize,
    lstride: usize,
) {
    assert!(l_n >= 1 && lstride >= row, "fanout_rows geometry");
    assert!(
        base + (l_n - 1) * lstride + row <= k.len() && k.len() == v.len(),
        "fanout_rows out of bounds"
    );
    let isa = usable(isa);
    let mut off = 0;
    while off < row {
        let n = BLOCK_ELEMS.min(row - off);
        // every layer of v mirrors k's layer-0 chunk (cross-buffer)
        for l in 0..l_n {
            let d = base + l * lstride + off;
            copy_with(isa, &mut v[d..d + n], &k[base + off..base + off + n]);
        }
        off += n;
    }
    if l_n > 1 {
        // k layers 1.. copy k layer 0 — same buffer, so split below the
        // first destination row (lstride >= row makes the split valid)
        let (head, tail) = k.split_at_mut(base + row);
        let src = &head[base..];
        let mut off = 0;
        while off < row {
            let n = BLOCK_ELEMS.min(row - off);
            for l in 1..l_n {
                let d = l * lstride - row + off;
                copy_with(isa, &mut tail[d..d + n], &src[off..off + n]);
            }
            off += n;
        }
    }
}

// ---------------------------------------------------------------------------
// widening gather/scatter: f32 slab <-> little-endian cold-tier bytes
// ---------------------------------------------------------------------------

/// Widening scatter: append `src` to `out` as little-endian f32 bytes
/// (the suspend-to-cold-tier spill). One bulk byte move on
/// little-endian targets; per-element `to_le_bytes` elsewhere.
pub fn spill_f32_le(out: &mut Vec<u8>, src: &[f32]) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: f32 has no invalid bit patterns and its in-memory
        // layout on a little-endian target IS its to_le_bytes order;
        // the reinterpreted slice is read-only and scoped to this call.
        let bytes = unsafe {
            std::slice::from_raw_parts(src.as_ptr().cast::<u8>(), src.len() * 4)
        };
        out.extend_from_slice(bytes);
    }
    #[cfg(not(target_endian = "little"))]
    for x in src {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Widening gather: decode little-endian f32 bytes into `dst` (the
/// resume-from-cold-tier unspill). Inverse of [`spill_f32_le`].
pub fn unspill_f32_le(bytes: &[u8], dst: &mut [f32]) {
    assert_eq!(bytes.len(), dst.len() * 4, "unspill length mismatch");
    #[cfg(target_endian = "little")]
    {
        // SAFETY: any 4 bytes are a valid f32 bit pattern; on a
        // little-endian target the raw store equals from_le_bytes.
        let db = unsafe {
            std::slice::from_raw_parts_mut(
                dst.as_mut_ptr().cast::<u8>(),
                dst.len() * 4,
            )
        };
        db.copy_from_slice(bytes);
    }
    #[cfg(not(target_endian = "little"))]
    for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
        *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn force_scalar_policy_pins_fallback() {
        assert_eq!(detect(true), Isa::Scalar);
        // without the pin, detection returns whatever the CPU supports
        // and never panics
        let _ = detect(false).label();
    }

    #[test]
    fn active_isa_is_cached_and_stable() {
        let a = active_isa();
        assert_eq!(a, active_isa());
        assert!(!a.label().is_empty());
    }

    #[test]
    fn usable_clamps_to_cpu() {
        // whatever is requested, the result is executable here
        for isa in [Isa::Avx2, Isa::Neon, Isa::Scalar] {
            let _ = usable(isa).label();
        }
        assert_eq!(usable(Isa::Scalar), Isa::Scalar);
    }

    #[test]
    fn copy_matches_scalar_reference() {
        let src: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 7.0).collect();
        let mut dst = vec![0.0f32; 100];
        copy(&mut dst, &src);
        assert_eq!(dst, src);
    }

    #[test]
    fn fill_covers_tails() {
        for n in [0usize, 1, 7, 8, 9, 31, 32, 33, 100] {
            let mut d = vec![1.0f32; n];
            fill(&mut d, -2.5);
            assert!(d.iter().all(|&x| x == -2.5), "n={n}");
            let mut di = vec![1i32; n];
            fill_i32(&mut di, 42);
            assert!(di.iter().all(|&x| x == 42), "n={n}");
        }
    }

    #[test]
    fn copy_2d_strided_rows() {
        // 3 rows of 4 from a stride-6 source into a stride-5 dest
        let src: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let mut dst = vec![0.0f32; 17];
        copy_2d(&mut dst, 1, 5, &src, 2, 6, 3, 4);
        for r in 0..3 {
            for c in 0..4 {
                assert_eq!(dst[1 + r * 5 + c], (2 + r * 6 + c) as f32);
            }
        }
    }

    #[test]
    fn fanout_rows_replicates_layer_zero() {
        // 3 layers, 2 lanes (lstride = 2*row), lane 1
        let (row, l_n) = (10usize, 3usize);
        let lstride = 2 * row;
        let base = row; // lane 1
        let mut k = vec![0.0f32; l_n * lstride];
        let mut v = vec![0.0f32; l_n * lstride];
        for (i, x) in k[base..base + row].iter_mut().enumerate() {
            *x = i as f32 + 1.0;
        }
        fanout_rows(&mut k, &mut v, base, row, l_n, lstride);
        for l in 0..l_n {
            let o = base + l * lstride;
            for i in 0..row {
                assert_eq!(k[o + i], i as f32 + 1.0, "k l={l} i={i}");
                assert_eq!(v[o + i], i as f32 + 1.0, "v l={l} i={i}");
            }
        }
        // other lane untouched
        assert_eq!(k[0], 0.0);
        assert_eq!(v[0], 0.0);
    }

    #[test]
    fn spill_roundtrip() {
        let src: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let mut bytes = Vec::new();
        spill_f32_le(&mut bytes, &src);
        assert_eq!(bytes.len(), src.len() * 4);
        // matches the element-wise encoding exactly
        for (i, x) in src.iter().enumerate() {
            assert_eq!(&bytes[i * 4..i * 4 + 4], &x.to_le_bytes());
        }
        let mut back = vec![0.0f32; src.len()];
        unspill_f32_le(&bytes, &mut back);
        assert_eq!(back, src);
    }
}
