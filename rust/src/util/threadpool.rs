//! Bounded thread pools.
//!
//! Two facilities share this module:
//! * [`ThreadPool`] — long-lived workers for connection handling (the
//!   HTTP front-end must not spawn unboundedly under load);
//! * [`scoped`] — run a finite job list to completion with bounded
//!   parallelism while *borrowing from the caller's stack*. This is
//!   what the decode-path executors (scheduler chunk fan-out, router
//!   group fan-out) are built on: their jobs borrow the runtime,
//!   weights, and result slots, so the `'static` channel-fed pool
//!   cannot host them.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run every job to completion on at most `max_threads` scoped worker
/// threads (plus nothing else: with one thread, or one job, the jobs
/// run inline). Jobs may borrow non-`'static` data; panics propagate
/// after all workers join, and job order is never load-bearing — the
/// decode executors write results into per-job slots and reassemble
/// deterministically.
pub fn scoped<F>(max_threads: usize, jobs: Vec<F>)
where
    F: FnOnce() + Send,
{
    let n = max_threads.max(1).min(jobs.len());
    if n <= 1 {
        for job in jobs {
            job();
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<F>>> =
        jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    std::thread::scope(|s| {
        for _ in 0..n {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= slots.len() {
                    break;
                }
                let job = slots[i].lock().expect("job slot poisoned").take();
                if let Some(job) = job {
                    job();
                }
            });
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("cdlm-http-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("job queue poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => return, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Queue a job; blocks never (unbounded queue, bounded parallelism).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers gone");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain + exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(3);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = count.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn parallelism_is_bounded_but_present() {
        let pool = ThreadPool::new(2);
        let active = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let a = active.clone();
            let p = peak.clone();
            pool.execute(move || {
                let now = a.fetch_add(1, Ordering::SeqCst) + 1;
                p.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(10));
                a.fetch_sub(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        let peak = peak.load(Ordering::SeqCst);
        assert!(peak <= 2, "exceeded pool size: {peak}");
        assert!(peak >= 1);
    }

    #[test]
    #[should_panic]
    fn zero_size_panics() {
        let _ = ThreadPool::new(0);
    }

    #[test]
    fn scoped_runs_all_jobs_and_borrows_stack() {
        let mut results = vec![0usize; 17];
        let jobs: Vec<_> = results
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| move || *slot = i + 1)
            .collect();
        scoped(3, jobs);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(*r, i + 1, "job {i} did not run");
        }
    }

    #[test]
    fn scoped_bounds_parallelism() {
        let active = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..12)
            .map(|_| {
                let a = active.clone();
                let p = peak.clone();
                move || {
                    let now = a.fetch_add(1, Ordering::SeqCst) + 1;
                    p.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(5));
                    a.fetch_sub(1, Ordering::SeqCst);
                }
            })
            .collect();
        scoped(2, jobs);
        let peak = peak.load(Ordering::SeqCst);
        assert!(peak <= 2, "exceeded scoped thread bound: {peak}");
        assert!(peak >= 1);
    }

    #[test]
    fn scoped_single_thread_runs_inline() {
        let mut vals = [0, 0];
        {
            let jobs: Vec<_> = vals
                .iter_mut()
                .enumerate()
                .map(|(i, v)| move || *v = i + 10)
                .collect();
            scoped(1, jobs);
        }
        assert_eq!(vals, [10, 11]);
    }
}
