//! Bounded thread pool for connection handling (the HTTP front-end must
//! not spawn unboundedly under load; decode concurrency is separately
//! bounded by the router's single worker + batcher).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("cdlm-http-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => return, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Queue a job; blocks never (unbounded queue, bounded parallelism).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers gone");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain + exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(3);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = count.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn parallelism_is_bounded_but_present() {
        let pool = ThreadPool::new(2);
        let active = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let a = active.clone();
            let p = peak.clone();
            pool.execute(move || {
                let now = a.fetch_add(1, Ordering::SeqCst) + 1;
                p.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(10));
                a.fetch_sub(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        let peak = peak.load(Ordering::SeqCst);
        assert!(peak <= 2, "exceeded pool size: {peak}");
        assert!(peak >= 1);
    }

    #[test]
    #[should_panic]
    fn zero_size_panics() {
        let _ = ThreadPool::new(0);
    }
}
