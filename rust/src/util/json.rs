//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers the full JSON grammar we exchange with the python build path
//! (manifest.json, vocab.json, eval sets, golden fixtures) plus
//! serialization for the HTTP API and metrics endpoints. Numbers are
//! stored as f64 (fine: all our integers are < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<i32> (token id lists).
    pub fn as_i32_vec(&self) -> Option<Vec<i32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_i64()).map(|v| v as i32).collect())
    }

    // ---- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
        Json::Arr(it.into_iter().collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        // the scanner loop above only ever advances over ASCII bytes
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the full sequence
                    let len = utf8_len(c);
                    let start = self.pos - 1;
                    self.pos += len - 1;
                    let slice = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| self.err("bad utf8"))?;
                    out.push_str(
                        std::str::from_utf8(slice)
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        c if c >= 0xF0 => 4,
        c if c >= 0xE0 => 3,
        _ => 2,
    }
}

pub fn load(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip_display_parse() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr([Json::str("a\"b"), Json::Bool(false)])),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\"A"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn i32_vec() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.as_i32_vec().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::num(256.0).to_string(), "256");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }
}
