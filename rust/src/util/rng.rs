//! Deterministic RNG mirrored byte-for-byte with python `tasks.SplitMix64`.
//!
//! The workload generators on both sides of the language boundary must
//! produce identical prompt streams; python pins reference outputs in
//! its tests and `artifacts/golden/tasks.json` pins cross-language parity.

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (mod bias negligible for tiny n; matches python).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform usize in [0, n) — convenience for indexing.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_reference_values_match_python() {
        // mirrored in python/tests/test_tasks.py
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn determinism() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
