//! std-only infrastructure: JSON, RNG, CLI, stats, property testing.
//!
//! The offline registry only carries the `xla` crate closure, so the
//! usual suspects (serde, clap, rand, criterion, proptest) are replaced
//! by these small, fully-tested modules.

pub mod alloc_count;
pub mod cli;
pub mod json;
pub mod kernels;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
