//! Counting allocator shim for the hot-path allocation gate.
//!
//! [`CountingAlloc`] delegates every request to the system allocator and
//! bumps two counters on each *acquisition* (alloc / alloc_zeroed /
//! realloc — frees are not counted, the gate cares about demand, not
//! balance): a process-wide total and a per-thread count. The hotpath
//! bench diffs [`thread_allocs`] around the steady-state decode window
//! and hard-fails if the delta is nonzero.
//!
//! The shim is **not** installed by the library: binaries that want the
//! gate opt in with
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: cdlm::util::alloc_count::CountingAlloc = CountingAlloc;
//! ```
//!
//! (the `cdlm` CLI and the `hot_path` integration test do). Everything
//! else — the library unit tests, the other integration-test binaries,
//! the benches — keeps the plain system allocator, so the counters read
//! zero there and [`counting_enabled`] reports whether the shim is
//! live. Counting costs one relaxed atomic increment plus one TLS
//! bump per acquisition; it is cheap enough to leave on for every
//! `cdlm` subcommand.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static PROCESS_ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// A `#[global_allocator]` that counts heap acquisitions. Zero-sized;
/// safe to construct in a `static`.
pub struct CountingAlloc;

impl CountingAlloc {
    #[inline]
    fn bump() {
        PROCESS_ALLOCS.fetch_add(1, Ordering::Relaxed);
        // try_with: TLS may already be torn down when a thread's own
        // destructors free memory — those frees still allocate nothing,
        // but a realloc there must not abort the process.
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
    }
}

// SAFETY: pure pass-through to `System`; the counters never influence
// the returned pointers or layouts.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::bump();
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::bump();
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        // a realloc is a fresh acquisition even when it shrinks or
        // resizes in place: the hot path must not reach the allocator
        // at all, so any call counts against the gate
        Self::bump();
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

/// Heap acquisitions performed by the calling thread since it started.
/// Reads 0 when [`CountingAlloc`] is not installed.
pub fn thread_allocs() -> u64 {
    THREAD_ALLOCS.try_with(Cell::get).unwrap_or(0)
}

/// Process-wide heap acquisitions. Reads 0 when [`CountingAlloc`] is
/// not installed.
pub fn process_allocs() -> u64 {
    PROCESS_ALLOCS.load(Ordering::Relaxed)
}

/// Whether the counting allocator is actually the global allocator of
/// this binary: forces one boxed allocation and checks that the
/// thread-local counter moved. Gate drivers call this first so a
/// mis-wired binary fails loudly instead of "measuring" zero allocs
/// with a counter nothing increments.
pub fn counting_enabled() -> bool {
    let before = thread_allocs();
    drop(std::hint::black_box(Box::new(0u64)));
    thread_allocs() > before
}

#[cfg(test)]
mod tests {
    use super::*;

    // The library test binary does NOT install the shim, so these
    // tests pin the uninstalled behavior; tests/hot_path.rs installs
    // it and pins the counting behavior.

    #[test]
    fn uninstalled_counters_stay_flat() {
        assert!(!counting_enabled());
        let before = thread_allocs();
        drop(std::hint::black_box(vec![0u8; 4096]));
        assert_eq!(thread_allocs(), before);
        assert_eq!(process_allocs(), 0);
    }
}
