//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a predicate over `cases` random
//! inputs drawn from a deterministic per-test seed; on failure it reports
//! the case seed so the exact input can be replayed. No shrinking — our
//! generators are kept small enough that raw seeds are debuggable.

use super::rng::SplitMix64;

/// Run `f` for `cases` deterministic random cases. Panics with the
/// failing case seed if `f` panics or returns false.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut SplitMix64) -> bool,
{
    // derive a stable seed from the test name
    let mut seed = 0xC0FFEEu64;
    for b in name.bytes() {
        seed = seed.wrapping_mul(31).wrapping_add(b as u64);
    }
    for case in 0..cases {
        let case_seed = seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = SplitMix64::new(case_seed);
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        match ok {
            Ok(true) => {}
            Ok(false) => panic!(
                "property '{name}' failed at case {case} (seed {case_seed:#x})"
            ),
            Err(e) => panic!(
                "property '{name}' panicked at case {case} (seed {case_seed:#x}): {e:?}"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |r| {
            let (a, b) = (r.below(1000) as i64, r.below(1000) as i64);
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_seed() {
        check("always-false-eventually", 50, |r| r.below(10) != 3);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen = Vec::new();
        check("record", 5, |r| {
            seen.push(r.next_u64());
            true
        });
        let mut seen2 = Vec::new();
        check("record", 5, |r| {
            seen2.push(r.next_u64());
            true
        });
        assert_eq!(seen, seen2);
    }
}
