//! Summary statistics + a tiny bench timer (criterion is unavailable).

use std::time::{Duration, Instant};

/// Running summary of a sample of f64s.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn count(&self) -> usize {
        self.xs.len()
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.sum() / self.xs.len() as f64
        }
    }

    pub fn std(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Fold another summary's samples into this one. Exact: the merged
    /// summary is indistinguishable from one that saw every sample
    /// directly, so per-replica aggregates combine without drift.
    pub fn merge(&mut self, other: &Summary) {
        self.xs.extend_from_slice(&other.xs);
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut v = self.xs.clone();
        v.sort_by(f64::total_cmp);
        let pos = q / 100.0 * (v.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
        }
    }
}

/// Measure a closure: warmup runs then timed iterations; returns
/// per-iteration stats in seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - 1.29099).abs() < 1e-4);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for x in 0..101 {
            s.push(x as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(95.0), 95.0);
    }

    #[test]
    fn merge_is_exact() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut all = Summary::new();
        for x in [1.0, 5.0, 9.0] {
            a.push(x);
            all.push(x);
        }
        for x in [2.0, 4.0] {
            b.push(x);
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.sum(), all.sum());
        assert_eq!(a.percentile(50.0), all.percentile(50.0));
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
    }

    #[test]
    fn bench_runs() {
        let mut n = 0u64;
        let s = bench(1, 5, || n += 1);
        assert_eq!(s.count(), 5);
        assert_eq!(n, 6);
    }

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("us"));
    }
}
