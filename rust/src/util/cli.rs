//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("serve --port 8080 --verbose --tau=0.9 extra");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("tau"), Some("0.9"));
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--n 5 --x 1.5");
        assert_eq!(a.get_usize("n", 0), 5);
        assert_eq!(a.get_f64("x", 0.0), 1.5);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v");
        assert_eq!(a.get("a"), Some("true"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
