//! Toy character tokenizer — the rust mirror of `python/compile/vocab.py`.
//!
//! The table is compiled in (the vocab is part of the model contract),
//! and `Tokenizer::verify_against` cross-checks it against
//! `artifacts/vocab.json` at runtime-load time so the two languages can
//! never silently drift.

use std::collections::HashMap;

use crate::util::json::Json;

pub const PAD: i32 = 0;
pub const MASK: i32 = 1;
pub const BOS: i32 = 2;
pub const EOS: i32 = 3;
pub const VOCAB_SIZE: usize = 64;

const SYMBOLS: &str = "+-*=;#:?(),.><[] ";

#[derive(Debug, Clone)]
pub struct Tokenizer {
    tok_to_id: HashMap<char, i32>,
    id_to_tok: Vec<Option<char>>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut tok_to_id = HashMap::new();
        let mut id_to_tok = vec![None; VOCAB_SIZE];
        let mut idx = 4i32;
        let put = |ch: char, idx: &mut i32, t: &mut HashMap<char, i32>,
                       i: &mut Vec<Option<char>>| {
            t.insert(ch, *idx);
            i[*idx as usize] = Some(ch);
            *idx += 1;
        };
        for ch in "0123456789".chars() {
            put(ch, &mut idx, &mut tok_to_id, &mut id_to_tok);
        }
        for o in 0..26u8 {
            put((b'a' + o) as char, &mut idx, &mut tok_to_id, &mut id_to_tok);
        }
        for ch in SYMBOLS.chars() {
            put(ch, &mut idx, &mut tok_to_id, &mut id_to_tok);
        }
        assert!(idx as usize <= VOCAB_SIZE);
        Self { tok_to_id, id_to_tok }
    }

    pub fn encode(&self, text: &str) -> anyhow::Result<Vec<i32>> {
        text.chars()
            .map(|c| {
                self.tok_to_id
                    .get(&c)
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("unknown character {c:?}"))
            })
            .collect()
    }

    /// Decode ids, dropping specials; stops at the first `<eos>` when
    /// `stop_at_eos` (paper §A.3 generation-length accounting). The
    /// `stop_at_eos` form IS one [`Tokenizer::decode_stream`] call over
    /// a fresh [`StreamDecoder`], so the streamed-deltas-equal-one-shot
    /// contract holds by construction, not by parallel maintenance.
    pub fn decode(&self, ids: &[i32], stop_at_eos: bool) -> String {
        if stop_at_eos {
            return self.decode_stream(&mut StreamDecoder::new(), ids);
        }
        let mut out = String::new();
        for &i in ids {
            if (0..=3).contains(&i) {
                continue;
            }
            if let Some(Some(c)) = self.id_to_tok.get(i as usize) {
                out.push(*c);
            } else {
                out.push('?');
            }
        }
        out
    }

    /// Incrementally decode the next run of a streamed sequence.
    /// Equivalent to `decode(all_ids, true)` over the concatenation of
    /// every run fed so far: specials are dropped and the first `<eos>`
    /// terminates the stream, across run boundaries (a run after the
    /// `<eos>` run decodes to the empty string). The streaming serving
    /// path relies on this equivalence — `tests/streaming.rs` pins the
    /// concatenated deltas byte-identical to the one-shot decode.
    pub fn decode_stream(&self, st: &mut StreamDecoder, ids: &[i32]) -> String {
        if st.done {
            return String::new();
        }
        let mut out = String::new();
        for &i in ids {
            if i == EOS {
                st.done = true;
                break;
            }
            if (0..=3).contains(&i) {
                continue;
            }
            if let Some(Some(c)) = self.id_to_tok.get(i as usize) {
                out.push(*c);
            } else {
                out.push('?');
            }
        }
        out
    }

    /// Cross-check against the python-exported vocab.json.
    pub fn verify_against(&self, vocab_json: &Json) -> anyhow::Result<()> {
        let size = vocab_json.req("vocab_size")?.as_usize().unwrap_or(0);
        anyhow::ensure!(size == VOCAB_SIZE, "vocab size mismatch: {size}");
        for (k, v) in ["pad", "mask", "bos", "eos"].iter().zip([PAD, MASK, BOS, EOS]) {
            let got = vocab_json.req(k)?.as_i64().unwrap_or(-1) as i32;
            anyhow::ensure!(got == v, "{k} mismatch: {got} != {v}");
        }
        let map = vocab_json.req("id_to_tok")?.as_obj()
            .ok_or_else(|| anyhow::anyhow!("id_to_tok not an object"))?;
        for (id_str, tok) in map {
            let id: usize = id_str.parse()?;
            let t = tok.as_str().unwrap_or("");
            if t.starts_with('<') {
                continue; // specials already checked
            }
            let Some(ch) = t.chars().next() else {
                anyhow::bail!("token id {id} maps to an empty token");
            };
            anyhow::ensure!(
                self.id_to_tok.get(id) == Some(&Some(ch)),
                "token id {id} maps to {:?}, python says {ch:?}",
                self.id_to_tok.get(id)
            );
        }
        Ok(())
    }
}

/// Per-request incremental detokenizer state: carries the "saw
/// `<eos>`" bit across block-delta runs so a stream of
/// [`Tokenizer::decode_stream`] calls reproduces the one-shot
/// `decode(ids, true)` exactly, however the id sequence is split.
#[derive(Debug, Clone, Default)]
pub struct StreamDecoder {
    done: bool,
}

impl StreamDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// True once an `<eos>` has been fed: every later run decodes to "".
    pub fn finished(&self) -> bool {
        self.done
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let s = "q:3*4+5=?a:3*4=12;12+5=17;#17;";
        let ids = t.encode(s).unwrap();
        assert_eq!(t.decode(&ids, true), s);
    }

    #[test]
    fn specials_fixed() {
        assert_eq!((PAD, MASK, BOS, EOS), (0, 1, 2, 3));
    }

    #[test]
    fn digit_ids_match_python_layout() {
        let t = Tokenizer::new();
        // python: digits start at id 4
        assert_eq!(t.encode("0").unwrap(), vec![4]);
        assert_eq!(t.encode("9").unwrap(), vec![13]);
        assert_eq!(t.encode("a").unwrap(), vec![14]);
        assert_eq!(t.encode("z").unwrap(), vec![39]);
        assert_eq!(t.encode("+").unwrap(), vec![40]);
    }

    #[test]
    fn decode_stops_at_eos() {
        let t = Tokenizer::new();
        let mut ids = t.encode("#17").unwrap();
        ids.push(EOS);
        ids.extend(t.encode("junk").unwrap());
        assert_eq!(t.decode(&ids, true), "#17");
        assert_eq!(t.decode(&ids, false), "#17junk");
    }

    #[test]
    fn unknown_char_errors() {
        assert!(Tokenizer::new().encode("A").is_err());
    }

    #[test]
    fn stream_decode_matches_one_shot_for_any_split() {
        let t = Tokenizer::new();
        let mut ids = t.encode("#17").unwrap();
        ids.push(EOS);
        ids.extend(t.encode("junk").unwrap());
        ids.push(MASK);
        let want = t.decode(&ids, true);
        // every two-way split point, including before/after the eos
        for cut in 0..=ids.len() {
            let mut st = StreamDecoder::new();
            let mut got = t.decode_stream(&mut st, &ids[..cut]);
            got.push_str(&t.decode_stream(&mut st, &ids[cut..]));
            assert_eq!(got, want, "split at {cut}");
        }
        // and one token at a time
        let mut st = StreamDecoder::new();
        let got: String =
            ids.iter().map(|&i| t.decode_stream(&mut st, &[i])).collect();
        assert_eq!(got, want);
        assert!(st.finished());
    }

    #[test]
    fn decode_skips_mask_and_pad() {
        let t = Tokenizer::new();
        let ids = vec![PAD, BOS, 14, MASK, 15];
        assert_eq!(t.decode(&ids, true), "ab");
    }
}
