//! Roofline simulation (paper Appendix B.4, Fig. 9).
//!
//! A100-SXM4-80GB, dense FP16 tensor cores at boost clock:
//!     peak = 108 SM x 4 TC x 256 FMA x 1.41 GHz x 2 = 311.9 TFLOP/s
//!     bw   = 2039 GB/s          ridge = 153.0 FLOP/byte
//!
//! attainable(AI) = min(effective_peak, AI * bw). The paper notes the
//! observed plateau sits slightly below theoretical peak because softmax /
//! layer-norm run on vector units; `vector_fraction` models that mixed
//! ceiling.

use super::intensity::{DecodeMode, IntensityModel, StepCost};

#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// Peak matrix-unit throughput, FLOP/s.
    pub peak_flops: f64,
    /// Memory bandwidth, byte/s.
    pub bandwidth: f64,
    /// Fraction of FLOPs executed on vector units (lowers the ceiling).
    pub vector_fraction: f64,
    /// Vector-unit peak relative to tensor-core peak.
    pub vector_rel_peak: f64,
}

/// The paper's A100 parameterization.
pub const A100: Roofline = Roofline {
    peak_flops: 311.9e12,
    bandwidth: 2039.0e9,
    vector_fraction: 0.02,
    vector_rel_peak: 0.0625, // 19.5 TF/s FP32 vector vs 311.9 TF/s TC
};

impl Roofline {
    /// Theoretical ridge point in FLOP/byte (paper: 153.0).
    pub fn ridge(&self) -> f64 {
        self.peak_flops / self.bandwidth
    }

    /// Mixed-unit compute ceiling (slightly below tensor-core peak).
    pub fn effective_peak(&self) -> f64 {
        1.0 / ((1.0 - self.vector_fraction) / self.peak_flops
            + self.vector_fraction / (self.vector_rel_peak * self.peak_flops))
    }

    /// Attainable throughput (FLOP/s) at arithmetic intensity `ai`.
    pub fn attainable(&self, ai: f64) -> f64 {
        (ai * self.bandwidth).min(self.effective_peak())
    }

    /// Simulated step latency and throughput for a decode step cost.
    pub fn simulate(&self, cost: StepCost) -> RooflinePoint {
        let ai = cost.ai();
        let perf = self.attainable(ai);
        RooflinePoint {
            ai,
            attainable_tflops: perf / 1e12,
            step_latency_s: cost.flops / perf,
            memory_bound: ai < self.ridge(),
        }
    }

    pub fn simulate_mode(
        &self,
        model: &IntensityModel,
        mode: DecodeMode,
        bs: usize,
    ) -> RooflinePoint {
        self.simulate(model.step_cost(mode, bs))
    }
}

#[derive(Debug, Clone, Copy)]
pub struct RooflinePoint {
    pub ai: f64,
    pub attainable_tflops: f64,
    pub step_latency_s: f64,
    pub memory_bound: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::intensity::{ArchConfig, Workload};

    #[test]
    fn ridge_matches_paper() {
        // paper: 311.9 TF/s / 2039 GB/s ~= 153.0 FLOP/byte
        assert!((A100.ridge() - 153.0).abs() < 0.5);
    }

    #[test]
    fn effective_peak_below_theoretical() {
        let ep = A100.effective_peak();
        assert!(ep < A100.peak_flops);
        assert!(ep > 0.7 * A100.peak_flops);
    }

    #[test]
    fn attainable_piecewise() {
        assert!((A100.attainable(10.0) - 10.0 * A100.bandwidth).abs() < 1.0);
        assert_eq!(A100.attainable(1e6), A100.effective_peak());
    }

    #[test]
    fn ar_memory_bound_vanilla_compute_bound() {
        let m = IntensityModel::new(ArchConfig::llada_8b(), Workload::paper());
        let ar_m = IntensityModel::new(ArchConfig::llama31_8b(), Workload::paper());
        assert!(A100.simulate_mode(&ar_m, DecodeMode::Ar, 1).memory_bound);
        assert!(
            !A100
                .simulate_mode(&m, DecodeMode::VanillaDlm, 1)
                .memory_bound
        );
    }

    #[test]
    fn block_dlm_perf_saturates_with_batch() {
        // paper Fig. 9: B=32 saturates around bs=8
        let m = IntensityModel::new(ArchConfig::llada_8b(), Workload::paper());
        let mode = DecodeMode::BlockDlm { block: 32 };
        let p8 = A100.simulate_mode(&m, mode, 8).attainable_tflops;
        let p128 = A100.simulate_mode(&m, mode, 128).attainable_tflops;
        assert!(p128 / p8 < 1.15, "should be nearly flat: {p8} -> {p128}");
    }

    #[test]
    fn vanilla_latency_exceeds_block_latency() {
        // per-step latency: recomputing 768 tokens costs more than 32
        let m = IntensityModel::new(ArchConfig::llada_8b(), Workload::paper());
        let v = A100.simulate_mode(&m, DecodeMode::VanillaDlm, 1);
        let b = A100.simulate_mode(&m, DecodeMode::BlockDlm { block: 32 }, 1);
        assert!(v.step_latency_s > 5.0 * b.step_latency_s);
    }
}
