//! Arithmetic-intensity model of decoding (paper Fig. 4).
//!
//! AI = FLOPs / bytes-moved per decode step, as a function of batch size,
//! for three decode modes:
//!
//!   AR          1 token/step/seq, exact KV cache: weight traffic is
//!               amortized across the batch only -> memory-bound.
//!   VanillaDLM  recompute all S = Lp+Lg positions with full
//!               bidirectional attention each step, no KV reuse ->
//!               compute-bound even at bs = 1.
//!   BlockDLM(B) recompute only the B-token active block against an
//!               exact KV cache -> AI scales ~B at bs=1 (intra-block
//!               amortization), crossing the ridge at small batch.
//!
//! Traffic model (FP16 weights/activations):
//!   * model weights: read once per step (shared across batch);
//!   * KV cache: read per sequence (AR/Block modes);
//!   * un-fused attention intermediates (vanilla full attention only):
//!     score/softmax matrices in f32, one write + one read pass;
//!   * activation vectors: ~8 h-sized vectors per processed token/layer.
//!
//! With these terms the model lands within a few percent of every AI
//! value quoted in §5.4 (AR: 1.0/2.0/4.0/7.8 -> 71.3 at bs=128; vanilla:
//! 438.9 -> 1039.7; block-wise at bs=1: 4.0/15.8/31.1 for B=4/16/32).

/// Transformer architecture parameters (decode-relevant subset).
#[derive(Debug, Clone, Copy)]
pub struct ArchConfig {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// 3 for SwiGLU (gate/up/down), 2 for classic MLP.
    pub mlp_mats: usize,
}

impl ArchConfig {
    /// LLaMA-3.1-8B (GQA) — the paper's AR parameterization.
    pub fn llama31_8b() -> Self {
        ArchConfig {
            name: "LLaMA-3.1-8B",
            n_layers: 32,
            d_model: 4096,
            n_q_heads: 32,
            n_kv_heads: 8,
            d_head: 128,
            d_ff: 14336,
            vocab: 128_256,
            mlp_mats: 3,
        }
    }

    /// LLaDA-8B (MHA) — the paper's DLM parameterization.
    pub fn llada_8b() -> Self {
        ArchConfig {
            name: "LLaDA-8B",
            n_layers: 32,
            d_model: 4096,
            n_q_heads: 32,
            n_kv_heads: 32,
            d_head: 128,
            d_ff: 12288,
            vocab: 126_464,
            mlp_mats: 3,
        }
    }

    /// Total parameter count (attention + MLP + embedding + head).
    pub fn params(&self) -> f64 {
        let h = self.d_model as f64;
        let attn = (self.n_q_heads + 2 * self.n_kv_heads) as f64
            * self.d_head as f64
            * h
            + h * h; // o-proj
        let mlp = self.mlp_mats as f64 * h * self.d_ff as f64;
        self.n_layers as f64 * (attn + mlp) + 2.0 * self.vocab as f64 * h
    }

    /// KV-cache bytes per sequence at context length `ctx` (FP16).
    pub fn kv_bytes(&self, ctx: usize) -> f64 {
        2.0 * self.n_layers as f64
            * self.n_kv_heads as f64
            * self.d_head as f64
            * ctx as f64
            * 2.0
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DecodeMode {
    Ar,
    VanillaDlm,
    BlockDlm { block: usize },
}

impl DecodeMode {
    pub fn label(&self) -> String {
        match self {
            DecodeMode::Ar => "AR".to_string(),
            DecodeMode::VanillaDlm => "Vanilla DLM".to_string(),
            DecodeMode::BlockDlm { block } => format!("Block DLM B={block}"),
        }
    }
}

/// Decode-phase workload (paper: Lp=512, Lg=256, prefill excluded).
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub prompt_len: usize,
    pub gen_len: usize,
}

impl Workload {
    pub fn paper() -> Self {
        Workload { prompt_len: 512, gen_len: 256 }
    }

    fn full_seq(&self) -> usize {
        self.prompt_len + self.gen_len
    }
}

#[derive(Debug, Clone, Copy)]
pub struct StepCost {
    pub flops: f64,
    pub bytes: f64,
}

impl StepCost {
    pub fn ai(&self) -> f64 {
        self.flops / self.bytes
    }
}

pub struct IntensityModel {
    pub arch: ArchConfig,
    pub workload: Workload,
}

const WBYTES: f64 = 2.0; // FP16
const ACT_VECTORS: f64 = 8.0; // activation vectors r/w per token/layer

impl IntensityModel {
    pub fn new(arch: ArchConfig, workload: Workload) -> Self {
        Self { arch, workload }
    }

    /// FLOPs + bytes for one decode step at batch size `bs`.
    pub fn step_cost(&self, mode: DecodeMode, bs: usize) -> StepCost {
        let a = &self.arch;
        let w = &self.workload;
        let params = a.params();
        let h = a.d_model as f64;
        let l = a.n_layers as f64;
        let bsf = bs as f64;

        // tokens processed per step per sequence + attention context
        // (context = the full padded sequence: DLMs attend over all of
        // it, and the AR cache is sized for it — matching §5.4's setup)
        let s = w.full_seq();
        let (tokens, ctx, kv_read, unfused_attn) = match mode {
            DecodeMode::Ar => (1.0, s as f64, a.kv_bytes(s), false),
            DecodeMode::VanillaDlm => (s as f64, s as f64, 0.0, true),
            DecodeMode::BlockDlm { block } => {
                (block as f64, s as f64, a.kv_bytes(s), false)
            }
        };

        // ---- FLOPs: dense matmuls + attention (QK^T and PV)
        let dense = 2.0 * params * tokens;
        let attn = 4.0 * h * ctx * tokens * l;
        let flops = bsf * (dense + attn);

        // ---- bytes
        let weights = params * WBYTES;
        let act = ACT_VECTORS * tokens * h * l * WBYTES;
        let mut bytes = weights + bsf * (kv_read + act);
        if unfused_attn {
            // un-fused attention intermediates in f32: write scores,
            // read for softmax, write probabilities, read for PV
            let scores = 4.0 * ctx * ctx * a.n_q_heads as f64 * l * 4.0;
            bytes += bsf * scores;
        }
        StepCost { flops, bytes }
    }

    pub fn ai(&self, mode: DecodeMode, bs: usize) -> f64 {
        self.step_cost(mode, bs).ai()
    }

    /// Smallest batch size at which AI crosses `ridge` (None if never
    /// within `max_bs`).
    pub fn ridge_crossing(
        &self,
        mode: DecodeMode,
        ridge: f64,
        max_bs: usize,
    ) -> Option<usize> {
        (1..=max_bs).find(|&bs| self.ai(mode, bs) >= ridge)
    }
}

/// The batch sizes swept in Fig. 4 / Fig. 9.
pub const PAPER_BATCH_SIZES: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn model(arch: ArchConfig) -> IntensityModel {
        IntensityModel::new(arch, Workload::paper())
    }

    #[test]
    fn param_counts_match_8b() {
        assert!((ArchConfig::llama31_8b().params() - 8.0e9).abs() < 0.1e9);
        assert!((ArchConfig::llada_8b().params() - 8.0e9).abs() < 0.1e9);
    }

    #[test]
    fn ar_ai_matches_paper_small_batch() {
        // paper §5.4: 1.0 -> 2.0 -> 4.0 -> 7.8 for bs in {1,2,4,8}
        let m = model(ArchConfig::llama31_8b());
        let want = [(1, 1.0), (2, 2.0), (4, 4.0), (8, 7.8)];
        for (bs, ai) in want {
            let got = m.ai(DecodeMode::Ar, bs);
            assert!(
                (got - ai).abs() / ai < 0.06,
                "AR bs={bs}: got {got:.2}, paper {ai}"
            );
        }
    }

    #[test]
    fn ar_stays_memory_bound_at_128() {
        // paper: AI 71.3 at bs=128, below the 153 ridge
        let got = model(ArchConfig::llama31_8b()).ai(DecodeMode::Ar, 128);
        assert!((got - 71.3).abs() / 71.3 < 0.08, "got {got:.1}");
        assert!(got < 153.0);
    }

    #[test]
    fn vanilla_dlm_compute_bound_at_bs1() {
        // paper: 438.9 at bs=1 (already above the ridge)
        let got = model(ArchConfig::llada_8b()).ai(DecodeMode::VanillaDlm, 1);
        assert!((got - 438.9).abs() / 438.9 < 0.07, "got {got:.1}");
        assert!(got > 153.0);
    }

    #[test]
    fn vanilla_dlm_saturates() {
        // paper: 438.9 -> 619.2 -> 779.3; 1028.6 at 64 -> 1039.7 at 128
        let m = model(ArchConfig::llada_8b());
        for (bs, ai) in [(2, 619.2), (4, 779.3), (64, 1028.6), (128, 1039.7)] {
            let got = m.ai(DecodeMode::VanillaDlm, bs);
            assert!(
                (got - ai).abs() / ai < 0.08,
                "vanilla bs={bs}: got {got:.1}, paper {ai}"
            );
        }
        // near-saturation: <2% gain from 64 -> 128
        let gain = m.ai(DecodeMode::VanillaDlm, 128)
            / m.ai(DecodeMode::VanillaDlm, 64);
        assert!(gain < 1.02);
    }

    #[test]
    fn block_dlm_bs1_matches_paper() {
        // paper: AI 4.0 / 15.8 / 31.1 for B in {4,16,32} at bs=1
        let m = model(ArchConfig::llada_8b());
        for (b, ai) in [(4usize, 4.0), (16, 15.8), (32, 31.1)] {
            let got = m.ai(DecodeMode::BlockDlm { block: b }, 1);
            assert!(
                (got - ai).abs() / ai < 0.06,
                "block B={b}: got {got:.2}, paper {ai}"
            );
        }
    }

    #[test]
    fn block_dlm_crosses_ridge_at_small_batch() {
        // paper: B=32 crosses at bs ~ 8, B=16 at bs ~ 16
        let m = model(ArchConfig::llada_8b());
        let c32 = m
            .ridge_crossing(DecodeMode::BlockDlm { block: 32 }, 153.0, 256)
            .unwrap();
        let c16 = m
            .ridge_crossing(DecodeMode::BlockDlm { block: 16 }, 153.0, 256)
            .unwrap();
        assert!((5..=9).contains(&c32), "B=32 crossing at {c32}");
        assert!((10..=18).contains(&c16), "B=16 crossing at {c16}");
        assert!(c32 < c16);
    }

    #[test]
    fn ai_monotone_in_batch() {
        let m = model(ArchConfig::llada_8b());
        for mode in [
            DecodeMode::Ar,
            DecodeMode::VanillaDlm,
            DecodeMode::BlockDlm { block: 32 },
        ] {
            let mut prev = 0.0;
            for bs in PAPER_BATCH_SIZES {
                let ai = m.ai(mode, bs);
                assert!(ai >= prev, "{mode:?} not monotone at bs={bs}");
                prev = ai;
            }
        }
    }

    #[test]
    fn ordering_ar_block_vanilla() {
        // paper: block-wise sits between AR and vanilla at bs=1
        let m = model(ArchConfig::llada_8b());
        let ar = model(ArchConfig::llama31_8b()).ai(DecodeMode::Ar, 1);
        let blk = m.ai(DecodeMode::BlockDlm { block: 32 }, 1);
        let van = m.ai(DecodeMode::VanillaDlm, 1);
        assert!(ar < blk && blk < van);
    }
}
