//! System-level analysis (paper §5.4 + Appendix B.4).
//!
//! Unlike the serving benches (which run on this machine's CPU), these
//! modules are *analytic*: they model decoding FLOPs and memory traffic
//! for the paper's actual configurations (LLaMA-3.1-8B AR baseline,
//! LLaDA-8B vanilla/block-wise DLM) on an A100-SXM4-80GB, and therefore
//! reproduce the paper's Figure 4 / Figure 9 numbers directly.

pub mod intensity;
pub mod roofline;

pub use intensity::{ArchConfig, DecodeMode, IntensityModel, Workload};
pub use roofline::{Roofline, A100};
