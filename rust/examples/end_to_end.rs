//! End-to-end validation driver (the run recorded in EXPERIMENTS.md):
//! serve batched requests from every benchmark family through the full
//! stack for every method on both backbones, and report the paper's
//! metrics — TPS, per-sample latency, refinement steps, generation
//! length, accuracy — proving all three layers compose:
//!
//!   L1 Pallas block-attention + confidence kernels (inside the HLO)
//!   L2 AOT-lowered JAX student/teacher/AR programs
//!   L3 rust router -> batcher -> scheduler -> exact block KV cache
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end
//! ```
//! Env: CDLM_EVAL_N per-cell prompts (default 8), CDLM_BENCH_BS.

use cdlm::bench_support as bench;
use cdlm::coordinator::{DecodeOpts, Method};
use cdlm::workload::FAMILIES;

fn main() -> anyhow::Result<()> {
    let Some(mut core) = bench::require_artifacts("end_to_end") else {
        anyhow::bail!("artifacts missing — run `make artifacts`");
    };
    let n = bench::eval_n(8);
    let geom = core.rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    println!(
        "end-to-end serving validation: {} prompts/cell, decode bs={}, platform {}",
        n,
        bench::bench_bs(),
        core.rt.platform()
    );

    let methods = [
        Method::Vanilla,
        Method::DllmCache,
        Method::FastDllmPar,
        Method::FastDllmDc,
        Method::Cdlm,
        Method::Ar,
    ];
    let mut all = Vec::new();
    for backbone in ["dream", "llada"] {
        let mut rows = Vec::new();
        for fam in FAMILIES {
            for m in methods {
                let r = bench::run_cell(&mut core, backbone, m, fam, n, &opts)?;
                rows.push(r);
            }
        }
        bench::print_paper_table(
            &format!("end-to-end — {backbone} backbone"),
            backbone,
            &rows,
            Method::Vanilla,
        );
        // headline check: CDLM must beat the naive DLM on latency in
        // every family (the paper's 3.6x-14.5x claim, scaled)
        for fam in FAMILIES {
            let naive = rows
                .iter()
                .find(|r| r.family == fam && r.method == Method::Vanilla)
                .unwrap();
            let ours = rows
                .iter()
                .find(|r| r.family == fam && r.method == Method::Cdlm)
                .unwrap();
            let speedup = naive.latency_s / ours.latency_s.max(1e-9);
            println!(
                "  {}: CDLM latency speedup x{:.1}, step reduction x{:.1} {}",
                fam.name(),
                speedup,
                naive.steps / ours.steps.max(1e-9),
                if speedup > 1.0 { "(ok)" } else { "(!! slower than naive)" }
            );
        }
        all.extend(rows);
    }
    bench::save_results("end_to_end", bench::rows_to_json(&all));
    println!("\nKV pool peak in use: {}", core.pool.peak_in_use);
    Ok(())
}
