//! Quickstart: load the serving core, decode one math prompt with CDLM,
//! and compare against the naive diffusion baseline.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use cdlm::coordinator::{DecodeOpts, GroupKey, Method, ServingCore};
use cdlm::server::http::encode_user_prompt;
use cdlm::workload;

fn main() -> anyhow::Result<()> {
    let mut core = ServingCore::load(&cdlm::artifacts_dir(), 8)?;
    let geom = core.rt.manifest.geometry.clone();
    println!(
        "loaded {} AOT programs on {} (geometry: P={} Lg={} B={})",
        core.rt.manifest.programs.len(),
        core.rt.platform(),
        geom.prompt_len,
        geom.gen_len,
        geom.block_size
    );

    // a chain-arith problem with its 1-shot prefix, exactly like eval
    let sample = workload::generate(workload::Family::ChainArith, 1, 42)
        .pop()
        .unwrap();
    let enc = workload::encode_example(
        &core.tokenizer,
        workload::Family::ChainArith,
        &sample,
        geom.prompt_len,
        geom.gen_len,
    )?;
    println!("\nprompt:    {}", sample.prompt);
    println!("reference: {}", sample.answer);

    let opts = DecodeOpts::defaults(&geom);
    for method in [Method::Vanilla, Method::Cdlm] {
        let key = GroupKey::new("dream", method);
        let out = core
            .decode_group(&key, &[enc.prompt_ids.clone()], &opts)?
            .remove(0);
        let text = core.tokenizer.decode(&out.gen, true);
        println!(
            "\n[{:<8}] {} \n  steps {:>3}  model calls {:>3}  latency {:>7.1} ms  answer {:?} ({})",
            method.name(),
            text,
            out.steps,
            out.model_calls,
            out.latency.as_secs_f64() * 1e3,
            workload::extract_final(&text).unwrap_or("-"),
            if workload::score(&text, &sample) { "correct" } else { "wrong" },
        );
    }

    // same entry point the HTTP server uses
    let ids = encode_user_prompt(&core.tokenizer, "q:2+3*4=?", geom.prompt_len)?;
    let key = GroupKey::new("dream", Method::Cdlm);
    let out = core.decode_group(&key, &[ids], &opts)?.remove(0);
    println!(
        "\nad-hoc 'q:2+3*4=?' -> {:?} in {} steps",
        core.tokenizer.decode(&out.gen, true),
        out.steps
    );
    Ok(())
}
