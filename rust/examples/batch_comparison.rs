//! Batching behaviour of block-wise decoding (the §5.4 story, measured
//! on this box): decode the same request set at batch sizes {1, 2, 4}
//! and report per-step cost and aggregate TPS. Block-wise DLMs amortize
//! weight traffic across both the block and the batch, so per-request
//! cost should fall as the batch grows until compute saturates.
//!
//! ```text
//! cargo run --release --example batch_comparison
//! ```

use cdlm::coordinator::{DecodeOpts, GroupKey, Method, ServingCore};
use cdlm::workload::{self, Family};

fn main() -> anyhow::Result<()> {
    let mut core = ServingCore::load(&cdlm::artifacts_dir(), 16)?;
    let geom = core.rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let n = 4;
    let samples = workload::generate(Family::ListOp, n, 0xE7A1);
    let prompts: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                Family::ListOp,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .map(|e| e.prompt_ids)
        })
        .collect::<anyhow::Result<_>>()?;

    println!("method x batch-size grid over {n} list-op requests:\n");
    println!(
        "{:<14} {:>4} {:>12} {:>12} {:>10}",
        "method", "bs", "total(ms)", "ms/request", "agg TPS"
    );
    for method in [Method::Cdlm, Method::Ar, Method::Vanilla] {
        let key = GroupKey::new("dream", method);
        // warm-up every batch bucket (compiles are per-(program, bs))
        for bs in [1usize, 2, 4] {
            core.decode_group(&key, &prompts[..bs], &opts)?;
        }
        for bs in [1usize, 2, 4] {
            let t0 = std::time::Instant::now();
            let mut toks = 0usize;
            for chunk in prompts.chunks(bs) {
                let outs = core.decode_group(&key, chunk, &opts)?;
                toks += outs.iter().map(|o| o.gen_len).sum::<usize>();
            }
            let total = t0.elapsed().as_secs_f64();
            println!(
                "{:<14} {:>4} {:>12.1} {:>12.1} {:>10.1}",
                method.name(),
                bs,
                total * 1e3,
                total * 1e3 / n as f64,
                toks as f64 / total
            );
        }
        println!();
    }
    println!(
        "reading the shape (single-core CPU = compute-bound device):\n\
         - vanilla DLM: per-request cost RISES with bs — it is already\n\
           compute-saturated at bs=1, the Fig. 4 'vanilla DLM' regime;\n\
         - CDLM / AR: per-request cost roughly flat — their small\n\
           per-step compute amortizes fixed per-call overhead, the\n\
           memory-bound-to-ridge regime (on an accelerator these two\n\
           keep scaling until the ridge point, Fig. 9)."
    );
    Ok(())
}
