//! Speculative decoding extension (paper Appendix C): the CDLM student
//! drafts whole blocks, the equal-size AR model verifies them in one
//! parallel `ar_verify` pass per block.
//!
//! Checks the two properties that make the extension meaningful:
//!   1. output tokens are *identical* to plain AR greedy decoding
//!      (lossless speculation);
//!   2. the verifier runs far fewer passes than AR runs steps when the
//!      drafter agrees (the consistency training is what makes the
//!      drafts cheap — a naive DLM drafter would need ~Lg refinement
//!      steps per draft, Appendix C).
//!
//! ```text
//! cargo run --release --example spec_decode
//! ```

use cdlm::coordinator::methods::spec_decode;
use cdlm::coordinator::{DecodeOpts, GroupKey, KvPool, Method, ServingCore};
use cdlm::runtime::{ModelWeights, Programs};
use cdlm::workload::{self, Family};

fn main() -> anyhow::Result<()> {
    let mut core = ServingCore::load(&cdlm::artifacts_dir(), 16)?;
    let geom = core.rt.manifest.geometry.clone();
    if core
        .rt
        .manifest
        .find_program("ar_verify", 1, Some(geom.block_size))
        .is_none()
    {
        anyhow::bail!("ar_verify program missing from the manifest");
    }
    let n = 6;
    let samples = workload::generate(Family::ChainArith, n, 0xA11CE);
    let prompts: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .map(|e| e.prompt_ids)
        })
        .collect::<anyhow::Result<_>>()?;
    let opts = DecodeOpts::defaults(&geom);

    // plain AR baseline (ground truth for losslessness)
    let ar_key = GroupKey::new("dream", Method::Ar);
    let ar_outs = core.decode_group(&ar_key, &prompts, &opts)?;

    // speculative: CDLM drafts + AR verifies
    let draft_w = ModelWeights::load(&core.rt.manifest, "cdlm_dream")?;
    let verify_w = ModelWeights::load(&core.rt.manifest, "ar_dream")?;
    draft_w.upload(&core.rt)?;
    verify_w.upload(&core.rt)?;
    let draft = Programs::new(&core.rt, &draft_w);
    let verify = Programs::new(&core.rt, &verify_w);
    let mut pool = KvPool::new(&geom, 2 * n);
    let mut lossless = 0;
    let mut total_verify_passes = 0u64;
    let mut total_tokens = 0usize;
    println!(
        "{:<4} {:>9} {:>13} {:>9} {:>10}",
        "req", "AR steps", "verify calls", "tokens", "lossless?"
    );
    for (i, p) in prompts.iter().enumerate() {
        let outs = spec_decode::decode(
            &draft,
            &verify,
            &geom,
            &opts,
            &[p.as_slice()],
            &mut pool,
        )?;
        let o = &outs[0];
        let a = &ar_outs[i];
        // compare the generated prefix up to AR's <eos>
        let end = a
            .gen
            .iter()
            .position(|&t| t == cdlm::tokenizer::EOS)
            .map(|x| x + 1)
            .unwrap_or(a.gen.len());
        let same = o.gen[..end.min(o.gen.len())] == a.gen[..end];
        lossless += usize::from(same);
        total_verify_passes += o.model_calls;
        total_tokens += o.gen_len;
        println!(
            "{:<4} {:>9} {:>13} {:>9} {:>10}",
            i,
            a.steps,
            o.model_calls,
            o.gen_len,
            if same { "yes" } else { "NO" }
        );
    }
    println!(
        "\nlossless on {lossless}/{n}; verifier+drafter calls per token: {:.2}",
        total_verify_passes as f64 / total_tokens.max(1) as f64
    );
    println!("(AR alone costs 1 model call per token + prefill)");
    Ok(())
}
