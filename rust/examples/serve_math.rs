//! Serving demo: start the full HTTP stack (router + dynamic batcher +
//! decode worker), fire concurrent client requests at it over TCP, and
//! print per-request results plus the server's own /metrics aggregates.
//!
//! This exercises the real production path end to end: HTTP parse ->
//! admission -> batcher group/flush -> lockstep CDLM decode with exact
//! KV caching -> §A.3 metrics.
//!
//! ```text
//! cargo run --release --example serve_math
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::Router;
use cdlm::server::{self, http::ServerConfig};
use cdlm::workload::{self, Family};

fn http_post(addr: &str, path: &str, body: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    Ok(out.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
}

fn http_get(addr: &str, path: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n")?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    Ok(out.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
}

fn main() -> anyhow::Result<()> {
    let addr = "127.0.0.1:8473";
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(30),
            max_queue: 64,
            pool_capacity: 16,
            ..RouterConfig::default()
        },
    )?;
    // server thread
    let srv_addr = addr.to_string();
    std::thread::spawn(move || {
        let _ = server::serve(
            router,
            ServerConfig {
                addr: srv_addr,
                default_backbone: "dream".into(),
                io_timeout: Duration::from_secs(10),
                ..ServerConfig::default()
            },
        );
    });
    std::thread::sleep(Duration::from_millis(300));
    println!("health: {}", http_get(addr, "/healthz")?);

    // 8 concurrent clients: math questions via CDLM — the batcher should
    // group them into lockstep batches of up to 4. Clients prepend the
    // task family's few-shot prefix (same protocol as the eval harness).
    let shots = workload::few_shot_examples(Family::ChainArith);
    let prefix: String = shots
        .iter()
        .map(|s| format!("{}a:{};", s.prompt, s.answer))
        .collect();
    let samples = workload::generate(Family::ChainArith, 8, 99);
    let mut handles = Vec::new();
    for (i, s) in samples.iter().enumerate() {
        let addr = addr.to_string();
        let prompt = format!("{prefix}{}", s.prompt);
        let expect = s.final_answer.clone();
        handles.push(std::thread::spawn(move || {
            let body = format!(
                "{{\"prompt\": \"{prompt}\", \"method\": \"cdlm\"}}"
            );
            let resp = http_post(&addr, "/generate", &body)
                .unwrap_or_else(|e| format!("{{\"error\": \"{e}\"}}"));
            println!("client {i}: expect {expect} -> {resp}");
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    println!("\nserver metrics:\n{}", http_get(addr, "/metrics")?);
    Ok(())
}
