//! Paper Figure 7 (Appendix B.1): validation trends during CDLM training
//! — score rises/saturates while average refinement iterations fall.
//!
//! The series is logged by the CDLM-Dream training run in
//! `make artifacts` (eval hook) into `artifacts/fig7.json`; this bench
//! renders it and checks the paper's shape (iterations decrease).
//!
//! Run: `cargo bench --bench fig7_validation_trends`

use cdlm::util::json::{self, Json};

fn main() {
    let path = cdlm::artifacts_dir().join("fig7.json");
    let Ok(j) = json::load(&path) else {
        eprintln!("[fig7] skipped: {} missing — run `make artifacts`",
                  path.display());
        return;
    };
    let hist = j.req("history").unwrap().as_arr().unwrap_or_default();
    println!("\n=== Figure 7 — validation trends during CDLM-Dream training ===");
    println!("{:>8} {:>10} {:>12}", "step", "score", "avg steps");
    let mut max_steps: f64 = 0.0;
    for h in hist {
        let step = h.get("step").and_then(Json::as_f64).unwrap_or(0.0);
        let score = h.get("score").and_then(Json::as_f64).unwrap_or(0.0);
        let steps = h.get("steps").and_then(Json::as_f64).unwrap_or(0.0);
        println!("{step:>8.0} {:>10.3} {steps:>12.1}", score);
        max_steps = max_steps.max(steps);
    }
    // The paper's Fig. 7 point: training teaches multi-token
    // finalization, so refinement iterations sit far below the
    // teacher's N = Lg budget from early training on (checkpoint noise
    // on a small validation set is expected at this scale).
    let teacher_n = cdlm::runtime::Manifest::load(&cdlm::artifacts_dir())
        .map(|m| m.geometry.gen_len as f64)
        .unwrap_or(32.0);
    if max_steps > 0.0 && max_steps < 0.6 * teacher_n {
        println!(
            "\nshape check OK: every checkpoint's avg iterations ({max_steps:.1} worst) \
             is far below the teacher's N = {teacher_n:.0} budget (paper: step budget learned early)"
        );
    } else {
        println!(
            "\nshape check WARNING: iterations ({max_steps:.1}) not clearly below the teacher budget ({teacher_n:.0})"
        );
    }
}
