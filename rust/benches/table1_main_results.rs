//! Paper Table 1: main results on the Dream backbone.
//!
//! Five methods x four benchmark families, reporting TPS / latency /
//! steps / gen-length / score with speedups vs the naive DLM — the same
//! grid as the paper (methods and protocol identical; backbone and
//! hardware scaled — see rust/README.md).
//!
//! Run: `cargo bench --bench table1_main_results`
//! Env: CDLM_EVAL_N (prompts per cell, default 12), CDLM_BENCH_BS.

use cdlm::bench_support as bench;
use cdlm::coordinator::{DecodeOpts, Method};
use cdlm::workload::FAMILIES;

fn main() {
    let Some(mut core) = bench::require_artifacts("table1") else {
        return;
    };
    let n = bench::eval_n(12);
    let opts = DecodeOpts::defaults(&core.rt.manifest.geometry.clone());
    let methods = [
        Method::Vanilla,
        Method::DllmCache,
        Method::FastDllmPar,
        Method::FastDllmDc,
        Method::Cdlm,
    ];
    let mut rows = Vec::new();
    for fam in FAMILIES {
        for m in methods {
            match bench::run_cell(&mut core, "dream", m, fam, n, &opts) {
                Ok(r) => rows.push(r),
                Err(e) => eprintln!("[table1] {}/{}: {e:#}", fam.name(), m.name()),
            }
        }
    }
    bench::print_paper_table(
        "Table 1 — Dream backbone (families are the paper's GSM8K-CoT/MATH/HumanEval/MBPP analogues)",
        "Dream",
        &rows,
        Method::Vanilla,
    );
    bench::save_results("table1_dream", bench::rows_to_json(&rows));
}
