//! Runtime microbenchmarks (the §Perf profile targets): per-program
//! execute cost, KV pool view/commit cost, and the `util::kernels`
//! memory-primitive throughput (copy/splat/fan-out GB/s at block-,
//! page-, and slot-sized inputs) — the backend-level numbers
//! serving-latency regressions are diffed against. Runs on whichever
//! backend the serving core loads (reference when no artifacts are
//! present).
//!
//! Run: `cargo bench --bench microbench_runtime`

use cdlm::bench_support as bench;
use cdlm::coordinator::KvPool;
use cdlm::runtime::programs::{BlockStepOut, DenoiseOut, PrefillOut};
use cdlm::runtime::{Programs, TensorI32};
use cdlm::util::stats;

fn main() {
    let Some(core) = bench::require_artifacts("microbench") else {
        return;
    };
    let g = core.rt.manifest.geometry.clone();
    let weights =
        cdlm::runtime::ModelWeights::load(&core.rt.manifest, "cdlm_dream")
            .expect("weights");
    weights.upload(&core.rt).expect("upload");
    let progs = Programs::new(&core.rt, &weights);
    let (l, h, s, dh, b, p) =
        (g.n_layers, g.n_heads, g.seq_len, g.d_head, g.block_size, g.prompt_len);

    println!(
        "\n=== runtime microbench (per-call wall time, backend: {}) ===",
        core.rt.backend_name()
    );
    for bs in core.rt.manifest.buckets.clone() {
        let mut pool = KvPool::new(&g, bs);
        let leases: Vec<_> = (0..bs).map(|_| pool.alloc().unwrap()).collect();
        let lrefs: Vec<_> = leases.iter().collect();
        let kp = vec![0.5f32; l * bs * h * p * dh];
        for (lane, lease) in leases.iter().enumerate() {
            pool.write_prefill(lease, lane, bs, &kp, &kp).unwrap();
        }
        let vf = TensorI32::from_vec(&[bs], vec![0; bs]);
        let blk = TensorI32::from_vec(&[bs, b], vec![5; bs * b]);
        let ids = TensorI32::from_vec(&[bs, s], vec![5; bs * s]);
        let pids = TensorI32::from_vec(&[bs, p], vec![5; bs * p]);

        // writer-style outputs, reused across iterations like the
        // engines' step arenas — the measured call is allocation-free
        // once warm on the reference backend
        let mut blk_out = BlockStepOut::default();
        let st = stats::bench(2, 10, || {
            progs
                .student_block_step(bs, b, &pool.view(&lrefs), &vf, &blk,
                                    p as i32, &mut blk_out)
                .unwrap();
        });
        let mut den_out = DenoiseOut::default();
        let td = stats::bench(2, 10, || {
            progs.teacher_denoise(bs, &ids, &vf, &mut den_out).unwrap();
        });
        let mut pre_out = PrefillOut::default();
        let pf = stats::bench(2, 10, || {
            progs.student_prefill(bs, &pids, &vf, &mut pre_out).unwrap();
        });
        println!(
            "bs={bs}: block_step {:.3}ms  teacher_denoise {:.3}ms  prefill {:.3}ms  (denoise/block ratio {:.1}x)",
            st.mean() * 1e3,
            td.mean() * 1e3,
            pf.mean() * 1e3,
            td.mean() / st.mean().max(1e-12)
        );
    }

    // KV pool host-side costs: zero-copy view creation vs the batch-major
    // materialization device backends still pay behind the seam
    let bs = 4;
    let mut pool = KvPool::new(&g, bs);
    let leases: Vec<_> = (0..bs).map(|_| pool.alloc().unwrap()).collect();
    let lrefs: Vec<_> = leases.iter().collect();
    let kp = vec![0.5f32; l * bs * h * p * dh];
    for (lane, lease) in leases.iter().enumerate() {
        pool.write_prefill(lease, lane, bs, &kp, &kp).unwrap();
    }
    let view_cost = stats::bench(5, 100, || {
        let v = pool.view(&lrefs);
        std::hint::black_box(v.cache_len());
    });
    let gather_cost = stats::bench(5, 100, || {
        let (k, v) = pool.view(&lrefs).to_batch_major();
        std::hint::black_box((k.numel(), v.numel()));
    });
    println!(
        "kv view (bs=4, zero-copy): {:.2}us   batch-major materialize \
         (pjrt seam only): {:.1}us   bytes/lane: {}KiB",
        view_cost.mean() * 1e6,
        gather_cost.mean() * 1e6,
        pool.bytes_per_lane() / 1024
    );
    // one commit (append-only; repeated commits would overflow the lane)
    let kb = vec![0.5f32; l * bs * h * b * dh];
    let t0 = std::time::Instant::now();
    pool.commit_block(&leases[0], 0, bs, b, &kb, &kb).unwrap();
    println!("kv commit (one block): {:.1}us", t0.elapsed().as_secs_f64() * 1e6);

    // SIMD memory-kernel throughput: every slab walk above funnels
    // through these primitives; the same cells land in the
    // cdlm.bench.hotpath/v2 artifact as the per-kernel trend
    println!(
        "\n=== util::kernels throughput (isa: {}) ===",
        cdlm::util::kernels::active_isa().label()
    );
    println!(
        "{:<12} {:>6} {:>8} {:>12} {:>10}",
        "kernel", "class", "elems", "ns p50", "GB/s"
    );
    for c in cdlm::hotpath::run_kernel_cells(&g, 6) {
        println!(
            "{:<12} {:>6} {:>8} {:>12.0} {:>10.2}",
            c.kernel, c.size_class, c.elems, c.ns_p50, c.gbps
        );
    }
}
