//! Runtime microbenchmarks (the §Perf profile targets): per-program
//! execute cost and KV pool gather/commit cost — the backend-level
//! numbers serving-latency regressions are diffed against. Runs on
//! whichever backend the serving core loads (reference when no
//! artifacts are present).
//!
//! Run: `cargo bench --bench microbench_runtime`

use cdlm::bench_support as bench;
use cdlm::coordinator::KvPool;
use cdlm::runtime::{Programs, TensorF32, TensorI32};
use cdlm::util::stats;

fn main() {
    let Some(core) = bench::require_artifacts("microbench") else {
        return;
    };
    let g = core.rt.manifest.geometry.clone();
    let weights =
        cdlm::runtime::ModelWeights::load(&core.rt.manifest, "cdlm_dream")
            .expect("weights");
    weights.upload(&core.rt).expect("upload");
    let progs = Programs::new(&core.rt, &weights);
    let (l, h, s, dh, b, p) =
        (g.n_layers, g.n_heads, g.seq_len, g.d_head, g.block_size, g.prompt_len);

    println!(
        "\n=== runtime microbench (per-call wall time, backend: {}) ===",
        core.rt.backend_name()
    );
    for bs in core.rt.manifest.buckets.clone() {
        let kc = TensorF32::zeros(&[l, bs, h, s, dh]);
        let vc = TensorF32::zeros(&[l, bs, h, s, dh]);
        let vf = TensorI32::from_vec(&[bs], vec![0; bs]);
        let blk = TensorI32::from_vec(&[bs, b], vec![5; bs * b]);
        let ids = TensorI32::from_vec(&[bs, s], vec![5; bs * s]);
        let pids = TensorI32::from_vec(&[bs, p], vec![5; bs * p]);

        let st = stats::bench(2, 10, || {
            progs
                .student_block_step(bs, b, &kc, &vc, p as i32, &vf, &blk,
                                    p as i32)
                .unwrap();
        });
        let td = stats::bench(2, 10, || {
            progs.teacher_denoise(bs, &ids, &vf).unwrap();
        });
        let pf = stats::bench(2, 10, || {
            progs.student_prefill(bs, &pids, &vf).unwrap();
        });
        println!(
            "bs={bs}: block_step {:.3}ms  teacher_denoise {:.3}ms  prefill {:.3}ms  (denoise/block ratio {:.1}x)",
            st.mean() * 1e3,
            td.mean() * 1e3,
            pf.mean() * 1e3,
            td.mean() / st.mean().max(1e-12)
        );
    }

    // KV pool host-side costs
    let mut pool = KvPool::new(&g, 8);
    let id = pool.alloc().unwrap();
    let bs = 4;
    let kp = vec![0.5f32; l * bs * h * p * dh];
    pool.write_prefill(id, 0, bs, &kp, &kp);
    let kb = vec![0.5f32; l * bs * h * b * dh];
    let mut kout = vec![0.0f32; l * bs * h * s * dh];
    let mut vout = kout.clone();
    let ids1 = [id];
    let gather = stats::bench(5, 100, || {
        pool.gather_batch(&ids1, bs, &mut kout, &mut vout);
    });
    println!(
        "kv gather (1 lane into bs=4 buffer): {:.1}us   bytes/slot: {}KiB",
        gather.mean() * 1e6,
        pool.bytes_per_slot() / 1024
    );
    // one commit (append-only; repeated commits would overflow the slot)
    let t0 = std::time::Instant::now();
    pool.commit_block(id, 0, bs, b, &kb, &kb);
    println!("kv commit (one block): {:.1}us", t0.elapsed().as_secs_f64() * 1e6);
}
