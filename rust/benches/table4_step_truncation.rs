//! Paper Table 4: naive step truncation vs CDLM.
//!
//! Forcing the un-retrained teacher to finalize multiple tokens per step
//! (truncating its step budget to roughly CDLM's) collapses accuracy,
//! while CDLM holds quality at the same step count — the evidence that
//! consistency *training*, not just a smaller budget, enables
//! multi-token finalization.
//!
//! Run: `cargo bench --bench table4_step_truncation`

use cdlm::bench_support as bench;
use cdlm::coordinator::{DecodeOpts, Method};
use cdlm::util::json::Json;
use cdlm::workload::Family;

fn main() {
    let Some(mut core) = bench::require_artifacts("table4") else {
        return;
    };
    let n = bench::eval_n(16);
    let geom = core.rt.manifest.geometry.clone();
    let fam = Family::ChainArith; // the paper uses GSM8K here

    println!("\n=== Table 4 — naive step truncation vs CDLM (chain-arith) ===");
    println!(
        "{:<36} {:>12} {:>8} {:>8}",
        "Method", "Latency(s)", "Steps", "Score"
    );
    let mut results = Vec::new();
    for backbone in ["dream", "llada"] {
        // CDLM first, to learn its realized step count
        let opts = DecodeOpts::defaults(&geom);
        let cdlm_row =
            bench::run_cell(&mut core, backbone, Method::Cdlm, fam, n, &opts)
                .expect("cdlm cell");
        // truncate the teacher to a similar per-block budget
        let spb = ((cdlm_row.steps / geom.num_blocks() as f64).round()
            as usize)
            .max(1);
        let mut trunc_opts = DecodeOpts::defaults(&geom);
        trunc_opts.steps_per_block = Some(spb);
        let trunc_row = bench::run_cell(
            &mut core,
            backbone,
            Method::Vanilla,
            fam,
            n,
            &trunc_opts,
        )
        .expect("truncated cell");
        println!(
            "{:<36} {:>12.2} {:>8.1} {:>8.1}",
            format!("{backbone}-Instruct (truncated, spb={spb})"),
            trunc_row.latency_s,
            trunc_row.steps,
            trunc_row.score
        );
        println!(
            "{:<36} {:>12.2} {:>8.1} {:>8.1}",
            format!("CDLM-{backbone} (ours)"),
            cdlm_row.latency_s,
            cdlm_row.steps,
            cdlm_row.score
        );
        results.push(Json::obj(vec![
            ("backbone", Json::str(backbone)),
            ("truncated_steps", Json::num(trunc_row.steps)),
            ("truncated_score", Json::num(trunc_row.score)),
            ("truncated_latency_s", Json::num(trunc_row.latency_s)),
            ("cdlm_steps", Json::num(cdlm_row.steps)),
            ("cdlm_score", Json::num(cdlm_row.score)),
            ("cdlm_latency_s", Json::num(cdlm_row.latency_s)),
        ]));
    }
    bench::save_results("table4_step_truncation", Json::arr(results));
}
