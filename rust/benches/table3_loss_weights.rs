//! Paper Table 3: loss-weight composition ablation.
//!
//! The six (w_distill, w_cons, w_dlm) students are trained by
//! `make ablations` (python, build path); this bench formats the
//! resulting score / steps-to-convergence grid as the paper prints it.
//! Expected shape: consistency-only collapses; distillation anchors;
//! coupling both converges faster at equal-or-better score.
//!
//! Run: `make ablations && cargo bench --bench table3_loss_weights`

use cdlm::util::json::{self, Json};

fn main() {
    let path = cdlm::artifacts_dir().join("ablations").join("table3.json");
    let Ok(j) = json::load(&path) else {
        eprintln!(
            "[table3] skipped: {} missing — run `make ablations` first",
            path.display()
        );
        return;
    };
    let rows = j.req("rows").unwrap().as_arr().unwrap_or_default();
    println!("\n=== Table 3 — loss-weight ablation (CDLM-Dream) ===");
    println!(
        "{:>9} {:>7} {:>7} | {:>22} | {:>22}",
        "w_distill", "w_cons", "w_dlm", "chain-arith score(steps)",
        "alt-val score(steps)"
    );
    for r in rows {
        let g = |k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        println!(
            "{:>9.2} {:>7.2} {:>7.2} | {:>14.1} ({:>5.1}) | {:>14.1} ({:>5.1})",
            g("w_distill"),
            g("w_cons"),
            g("w_dlm"),
            g("score"),
            g("steps_to_convergence"),
            g("score_alt"),
            g("steps_alt"),
        );
    }
    // paper-shape check: consistency-only (row 2) must collapse relative
    // to distillation-anchored rows
    if rows.len() >= 3 {
        let g = |r: &Json, k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let cons_only = &rows[1];
        let coupled = &rows[2];
        if g(cons_only, "score") < g(coupled, "score") {
            println!(
                "\nshape check OK: consistency-only ({:.1}) < coupled ({:.1}) — matches paper row 2 collapse",
                g(cons_only, "score"),
                g(coupled, "score")
            );
        } else {
            println!("\nshape check WARNING: consistency-only did not underperform");
        }
    }
}
