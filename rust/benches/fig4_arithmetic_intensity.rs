//! Paper Figure 4: arithmetic intensity across batch sizes — analytic,
//! at the paper's own scale (LLaMA-3.1-8B AR, LLaDA-8B DLM, A100).
//! Reproduces the quoted AI values directly (within a few percent; the
//! unit tests in `analysis::intensity` pin them).
//!
//! Run: `cargo bench --bench fig4_arithmetic_intensity`

use cdlm::analysis::intensity::{
    ArchConfig, DecodeMode, IntensityModel, Workload, PAPER_BATCH_SIZES,
};
use cdlm::analysis::roofline::A100;
use cdlm::util::json::Json;

fn main() {
    let ar = IntensityModel::new(ArchConfig::llama31_8b(), Workload::paper());
    let dlm = IntensityModel::new(ArchConfig::llada_8b(), Workload::paper());
    let modes: Vec<(&str, &IntensityModel, DecodeMode)> = vec![
        ("AR (LLaMA-3.1-8B)", &ar, DecodeMode::Ar),
        ("Vanilla DLM (LLaDA-8B)", &dlm, DecodeMode::VanillaDlm),
        ("Block DLM B=4", &dlm, DecodeMode::BlockDlm { block: 4 }),
        ("Block DLM B=16", &dlm, DecodeMode::BlockDlm { block: 16 }),
        ("Block DLM B=32", &dlm, DecodeMode::BlockDlm { block: 32 }),
    ];
    println!(
        "\n=== Figure 4 — arithmetic intensity vs batch size (ridge {:.1} FLOP/B) ===",
        A100.ridge()
    );
    print!("{:<24}", "mode");
    for bs in PAPER_BATCH_SIZES {
        print!("{bs:>9}");
    }
    println!();
    let mut results = Vec::new();
    for (name, m, mode) in &modes {
        print!("{name:<24}");
        let mut series = Vec::new();
        for bs in PAPER_BATCH_SIZES {
            let ai = m.ai(*mode, bs);
            print!("{ai:>9.1}");
            series.push(Json::num(ai));
        }
        println!();
        results.push(Json::obj(vec![
            ("mode", Json::str(*name)),
            ("ai", Json::Arr(series)),
        ]));
    }
    println!("\npaper anchors: AR bs1-8 = 1.0/2.0/4.0/7.8, AR bs128 = 71.3;");
    println!("vanilla bs1 = 438.9 (compute-bound); block bs1 = 4.0/15.8/31.1 (B=4/16/32)");
    for (b, want) in [(32usize, 8usize), (16, 16)] {
        let got = dlm
            .ridge_crossing(DecodeMode::BlockDlm { block: b }, A100.ridge(), 256)
            .unwrap_or(0);
        println!("ridge crossing B={b}: bs ≈ {got} (paper ≈ {want})");
    }
    cdlm::bench_support::save_results("fig4_intensity", Json::arr(results));
}
