//! Paper Figure 9 (Appendix B.4): roofline placement of AR / vanilla /
//! block-wise decoding on the A100 (311.9 TF/s FP16, 2039 GB/s,
//! ridge 153.0) — attainable TFLOP/s and step latency per batch size.
//!
//! Run: `cargo bench --bench fig9_roofline`

use cdlm::analysis::intensity::{
    ArchConfig, DecodeMode, IntensityModel, Workload, PAPER_BATCH_SIZES,
};
use cdlm::analysis::roofline::A100;
use cdlm::util::json::Json;

fn main() {
    let ar = IntensityModel::new(ArchConfig::llama31_8b(), Workload::paper());
    let dlm = IntensityModel::new(ArchConfig::llada_8b(), Workload::paper());
    let modes: Vec<(&str, &IntensityModel, DecodeMode)> = vec![
        ("AR (LLaMA-3.1-8B)", &ar, DecodeMode::Ar),
        ("Vanilla DLM (LLaDA-8B)", &dlm, DecodeMode::VanillaDlm),
        ("Block DLM B=4", &dlm, DecodeMode::BlockDlm { block: 4 }),
        ("Block DLM B=16", &dlm, DecodeMode::BlockDlm { block: 16 }),
        ("Block DLM B=32", &dlm, DecodeMode::BlockDlm { block: 32 }),
    ];
    println!(
        "\n=== Figure 9 — roofline simulation (A100: {:.1} TF/s, {:.0} GB/s, ridge {:.1}, eff. peak {:.1} TF/s) ===",
        A100.peak_flops / 1e12,
        A100.bandwidth / 1e9,
        A100.ridge(),
        A100.effective_peak() / 1e12,
    );
    println!("attainable TFLOP/s per batch size:");
    print!("{:<24}", "mode");
    for bs in PAPER_BATCH_SIZES {
        print!("{bs:>9}");
    }
    println!();
    let mut results = Vec::new();
    for (name, m, mode) in &modes {
        print!("{name:<24}");
        let mut tf = Vec::new();
        let mut bound = Vec::new();
        for bs in PAPER_BATCH_SIZES {
            let p = A100.simulate_mode(m, *mode, bs);
            print!("{:>9.1}", p.attainable_tflops);
            tf.push(Json::num(p.attainable_tflops));
            bound.push(Json::str(if p.memory_bound { "mem" } else { "comp" }));
        }
        println!();
        results.push(Json::obj(vec![
            ("mode", Json::str(*name)),
            ("attainable_tflops", Json::Arr(tf)),
            ("bound", Json::Arr(bound)),
        ]));
    }
    // paper-shape saturation points: B=4 ~ bs 64, B=16 ~ bs 16, B=32 ~ bs 8
    println!("\nsaturation (first bs where attainable > 95% of ceiling):");
    for (b, want) in [(4usize, 64usize), (16, 16), (32, 8)] {
        let m = &dlm;
        let mode = DecodeMode::BlockDlm { block: b };
        let sat = PAPER_BATCH_SIZES
            .iter()
            .find(|&&bs| {
                A100.simulate_mode(m, mode, bs).attainable_tflops
                    > 0.95 * A100.effective_peak() / 1e12
            })
            .copied();
        println!("  B={b}: bs = {sat:?} (paper ≈ {want})");
    }
    cdlm::bench_support::save_results("fig9_roofline", Json::arr(results));
}
