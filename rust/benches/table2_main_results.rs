//! Paper Table 2: main results on the LLaDA backbone (the math-augmented
//! training-mixture variant — §5.2.2 / Appendix A.1).
//!
//! Run: `cargo bench --bench table2_main_results`

use cdlm::bench_support as bench;
use cdlm::coordinator::{DecodeOpts, Method};
use cdlm::workload::FAMILIES;

fn main() {
    let Some(mut core) = bench::require_artifacts("table2") else {
        return;
    };
    let n = bench::eval_n(12);
    let opts = DecodeOpts::defaults(&core.rt.manifest.geometry.clone());
    let methods = [
        Method::Vanilla,
        Method::DllmCache,
        Method::FastDllmPar,
        Method::FastDllmDc,
        Method::Cdlm,
    ];
    let mut rows = Vec::new();
    for fam in FAMILIES {
        for m in methods {
            match bench::run_cell(&mut core, "llada", m, fam, n, &opts) {
                Ok(r) => rows.push(r),
                Err(e) => eprintln!("[table2] {}/{}: {e:#}", fam.name(), m.name()),
            }
        }
    }
    bench::print_paper_table(
        "Table 2 — LLaDA backbone (math-augmented corpus)",
        "LLaDA",
        &rows,
        Method::Vanilla,
    );
    bench::save_results("table2_llada", bench::rows_to_json(&rows));
}
