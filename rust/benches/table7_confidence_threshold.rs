//! Paper Table 7 (Appendix B.2): token-level confidence threshold sweep.
//!
//! tau in {0.85, 0.90, 0.95} on the math and coding analogues:
//! conservative thresholds trade TPS for accuracy, aggressive ones the
//! reverse — the monotone trends of B.2, with 0.90 the robust default.
//!
//! Run: `cargo bench --bench table7_confidence_threshold`

use cdlm::bench_support as bench;
use cdlm::coordinator::{DecodeOpts, Method};
use cdlm::util::json::Json;
use cdlm::workload::Family;

fn main() {
    let Some(mut core) = bench::require_artifacts("table7") else {
        return;
    };
    let n = bench::eval_n(16);
    let geom = core.rt.manifest.geometry.clone();
    println!("\n=== Table 7 — confidence threshold sweep (CDLM-Dream) ===");
    println!(
        "{:<18} {:>6} {:>8} {:>12} {:>8} {:>8}",
        "Benchmark", "tau", "TPS", "Latency(s)", "Steps", "Score"
    );
    let mut results = Vec::new();
    for fam in [Family::ChainArith, Family::StrTransform] {
        for tau in [0.95f32, 0.90, 0.85] {
            let mut opts = DecodeOpts::defaults(&geom);
            opts.tau_conf = tau;
            let r = bench::run_cell(
                &mut core, "dream", Method::Cdlm, fam, n, &opts,
            )
            .expect("cell");
            println!(
                "{:<18} {:>6.2} {:>8.1} {:>12.2} {:>8.1} {:>8.1}",
                fam.name(),
                tau,
                r.tps,
                r.latency_s,
                r.steps,
                r.score
            );
            results.push(Json::obj(vec![
                ("family", Json::str(fam.name())),
                ("tau", Json::num(tau as f64)),
                ("tps", Json::num(r.tps)),
                ("latency_s", Json::num(r.latency_s)),
                ("steps", Json::num(r.steps)),
                ("score", Json::num(r.score)),
            ]));
        }
    }
    bench::save_results("table7_confidence_threshold", Json::arr(results));
}
