//! Paper Figure 3: throughput — naive DLM vs AR vs CDLM.
//!
//! Tokens/second on the math + coding analogues for both backbones
//! under (i) naive diffusion decoding, (ii) the equal-size AR baseline,
//! (iii) CDLM. Paper shape: CDLM >> naive DLM, and CDLM edges out AR
//! (multi-token finalization amortizes the per-step matrix-matrix cost).
//!
//! Run: `cargo bench --bench fig3_throughput_vs_ar`

use cdlm::bench_support as bench;
use cdlm::coordinator::{DecodeOpts, Method};
use cdlm::util::json::Json;
use cdlm::workload::Family;

fn main() {
    let Some(mut core) = bench::require_artifacts("fig3") else {
        return;
    };
    let n = bench::eval_n(12);
    let geom = core.rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let fams = [Family::ChainArith, Family::ListOp, Family::StrTransform];
    let methods = [Method::Vanilla, Method::Ar, Method::Cdlm];

    println!("\n=== Figure 3 — TPS: naive DLM vs AR vs CDLM ===");
    println!(
        "{:<10} {:<16} {:>12} {:>10} {:>10}",
        "backbone", "family", "naive-DLM", "AR", "CDLM"
    );
    let mut results = Vec::new();
    for backbone in ["dream", "llada"] {
        for fam in fams {
            let mut tps = Vec::new();
            for m in methods {
                let r = bench::run_cell(&mut core, backbone, m, fam, n, &opts)
                    .expect("cell");
                tps.push(r.tps);
            }
            println!(
                "{:<10} {:<16} {:>12.1} {:>10.1} {:>10.1}",
                backbone,
                fam.name(),
                tps[0],
                tps[1],
                tps[2]
            );
            results.push(Json::obj(vec![
                ("backbone", Json::str(backbone)),
                ("family", Json::str(fam.name())),
                ("tps_naive", Json::num(tps[0])),
                ("tps_ar", Json::num(tps[1])),
                ("tps_cdlm", Json::num(tps[2])),
            ]));
        }
    }
    bench::save_results("fig3_throughput", Json::arr(results));
}
