//! Paper Figure 8 (Appendix B.3): inference-time block-size sensitivity.
//!
//! The student was trained with B=8 (paper: 32); we sweep the
//! inference-time block size over the exported variants {2, 4, 8, 16}.
//! Paper shape: TPS rises with B up to the training block size, then
//! saturates/regresses (train-inference mismatch); accuracy peaks at the
//! training block size.
//!
//! Run: `cargo bench --bench fig8_block_size`

use cdlm::bench_support as bench;
use cdlm::coordinator::{DecodeOpts, Method};
use cdlm::util::json::Json;
use cdlm::workload::Family;

fn main() {
    let Some(mut core) = bench::require_artifacts("fig8") else {
        return;
    };
    let n = bench::eval_n(16);
    let geom = core.rt.manifest.geometry.clone();
    let mut blocks = core.rt.manifest.sweep_blocks.clone();
    blocks.push(geom.block_size);
    blocks.sort_unstable();

    println!("\n=== Figure 8 — inference block-size sweep (trained B={}) ===",
             geom.block_size);
    println!(
        "{:<10} {:>4} {:>8} {:>12} {:>8} {:>8}",
        "backbone", "B", "TPS", "Latency(s)", "Steps", "Score"
    );
    let mut results = Vec::new();
    // sweep programs were exported at bs=1 only
    std::env::set_var("CDLM_BENCH_BS", "1");
    for backbone in ["dream", "llada"] {
        for &b in &blocks {
            let mut opts = DecodeOpts::defaults(&geom);
            opts.block_size = b;
            let r = bench::run_cell(
                &mut core,
                backbone,
                Method::Cdlm,
                Family::ChainArith,
                n,
                &opts,
            )
            .expect("cell");
            println!(
                "{:<10} {:>4} {:>8.1} {:>12.2} {:>8.1} {:>8.1}",
                backbone, b, r.tps, r.latency_s, r.steps, r.score
            );
            results.push(Json::obj(vec![
                ("backbone", Json::str(backbone)),
                ("block", Json::num(b as f64)),
                ("tps", Json::num(r.tps)),
                ("latency_s", Json::num(r.latency_s)),
                ("steps", Json::num(r.steps)),
                ("score", Json::num(r.score)),
            ]));
        }
    }
    bench::save_results("fig8_block_size", Json::arr(results));
}
