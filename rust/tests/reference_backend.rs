//! Backend-trait contract tests on the deterministic reference backend.
//!
//! Golden property: with a fixed seed, the decode trace (tokens, steps,
//! model calls) of every one of the six methods is bit-identical across
//! independently constructed runtimes — on any machine. This is what
//! makes the artifact-free CI path a regression gate rather than a
//! smoke test: any change to engine control flow, KV pool plumbing, or
//! the reference model itself shifts a pinned trace.

use cdlm::coordinator::methods::{self, spec_decode};
use cdlm::coordinator::{DecodeOpts, DecodeOutcome, KvPool, Method, ALL_METHODS};
use cdlm::runtime::{ModelWeights, Programs, Runtime};
use cdlm::tokenizer::{Tokenizer, EOS, MASK};
use cdlm::workload::{self, Family};

const SEED: u64 = 0x5EED_0001;

fn prompts(n: usize) -> Vec<Vec<i32>> {
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let tok = Tokenizer::new();
    workload::generate(Family::ChainArith, n, 0xE7A1)
        .iter()
        .map(|s| {
            workload::encode_example(
                &tok,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect()
}

fn decode_all(seed: u64, prompts: &[Vec<i32>]) -> Vec<(Method, Vec<DecodeOutcome>)> {
    let rt = Runtime::reference(seed);
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let mut pool = KvPool::new(&geom, 16);
    let lanes: Vec<&[i32]> = prompts.iter().map(Vec::as_slice).collect();
    ALL_METHODS
        .iter()
        .map(|&m| {
            let w = ModelWeights::load(&rt.manifest, &m.weights_for("dream"))
                .unwrap();
            let progs = Programs::new(&rt, &w);
            let outs = methods::decode_batch(
                &progs, &geom, &opts, m, &lanes, &mut pool,
            )
            .unwrap();
            (m, outs)
        })
        .collect()
}

#[test]
fn same_seed_same_trace_across_all_six_methods() {
    let ps = prompts(2);
    let a = decode_all(SEED, &ps);
    let b = decode_all(SEED, &ps);
    for ((ma, outs_a), (mb, outs_b)) in a.iter().zip(&b) {
        assert_eq!(ma, mb);
        for (oa, ob) in outs_a.iter().zip(outs_b) {
            assert_eq!(oa.gen, ob.gen, "{} tokens drift across runs", ma.name());
            assert_eq!(oa.steps, ob.steps, "{} steps drift", ma.name());
            assert_eq!(
                oa.model_calls, ob.model_calls,
                "{} model calls drift",
                ma.name()
            );
            assert_eq!(oa.gen_len, ob.gen_len, "{} gen_len drift", ma.name());
        }
    }
}

#[test]
fn different_seed_changes_the_trace() {
    let ps = prompts(2);
    let a = decode_all(SEED, &ps);
    let b = decode_all(SEED ^ 0xFFFF, &ps);
    let drifted = a
        .iter()
        .zip(&b)
        .any(|((_, oa), (_, ob))| {
            oa.iter().zip(ob).any(|(x, y)| x.gen != y.gen)
        });
    assert!(drifted, "the seed must actually steer decode outputs");
}

#[test]
fn traces_stay_within_vocab_and_geometry() {
    let ps = prompts(2);
    for (m, outs) in decode_all(SEED, &ps) {
        for o in outs {
            assert!(o.steps >= 1, "{}: no refinement steps", m.name());
            assert!(o.model_calls >= o.steps, "{}: calls < steps", m.name());
            for &t in &o.gen {
                assert!(
                    t == MASK || (0..64).contains(&t),
                    "{}: token {t} out of vocab",
                    m.name()
                );
            }
        }
    }
}

#[test]
fn speculative_decode_is_lossless_vs_ar_greedy() {
    let ps = prompts(2);
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let mut pool = KvPool::new(&geom, 16);

    let ar_w = ModelWeights::load(&rt.manifest, "ar_dream").unwrap();
    let ar_progs = Programs::new(&rt, &ar_w);
    let lanes: Vec<&[i32]> = ps.iter().map(Vec::as_slice).collect();
    let ar_outs = methods::decode_batch(
        &ar_progs, &geom, &opts, Method::Ar, &lanes, &mut pool,
    )
    .unwrap();

    let draft_w = ModelWeights::load(&rt.manifest, "cdlm_dream").unwrap();
    let draft_progs = Programs::new(&rt, &draft_w);
    for (i, p) in ps.iter().enumerate() {
        let outs = spec_decode::decode(
            &draft_progs,
            &ar_progs,
            &geom,
            &opts,
            &[p.as_slice()],
            &mut pool,
        )
        .unwrap();
        let a = &ar_outs[i];
        let end = a
            .gen
            .iter()
            .position(|&t| t == EOS)
            .map(|x| x + 1)
            .unwrap_or(a.gen.len());
        assert_eq!(
            &outs[0].gen[..end],
            &a.gen[..end],
            "speculative decode diverged from AR greedy at row {i}"
        );
    }
    assert_eq!(pool.in_use(), 0, "spec decode leaked KV slots");
}
