//! Router + serving-path integration: the full channel architecture
//! (submit -> admission -> dynamic batcher -> decode worker -> response)
//! plus failure injection (bad requests, admission limits, shutdown).
//! Runs hermetically: without an artifacts directory the worker loads
//! the deterministic reference backend.

use std::time::Duration;

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::{GenerateRequest, Method, Router};
use cdlm::server::http::encode_user_prompt;
use cdlm::tokenizer::Tokenizer;
use cdlm::workload::{self, Family};

fn start_router() -> Router {
    Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(10),
            max_queue: 8,
            pool_capacity: 8,
            ..RouterConfig::default()
        },
    )
    .expect("router starts")
}

fn valid_request(method: Method) -> GenerateRequest {
    let tok = Tokenizer::new();
    let s = workload::generate(Family::ListOp, 1, 77).pop().unwrap();
    GenerateRequest::new(
        "dream",
        method,
        encode_user_prompt(&tok, &s.prompt, 64).unwrap(),
    )
}

#[test]
fn request_roundtrip_through_worker() {
    let router = start_router();
    let handle = router.submit(valid_request(Method::Cdlm)).unwrap();
    let resp = handle.wait().expect("decode ok");
    assert!(resp.steps >= 1);
    assert!(resp.gen_len <= router.geometry.gen_len);
    assert!(!resp.gen_ids.is_empty());
    router.shutdown();
}

#[test]
fn concurrent_requests_are_batched() {
    let router = start_router();
    let handles: Vec<_> = (0..4)
        .map(|_| router.submit(valid_request(Method::Cdlm)).unwrap())
        .collect();
    let mut ok = 0;
    for h in handles {
        if h.wait().is_ok() {
            ok += 1;
        }
    }
    assert_eq!(ok, 4, "all concurrent requests must be answered");
    // metrics must have counted them
    let m = router.metrics().unwrap();
    let cell = m.get("dream/cdlm").expect("metrics cell exists");
    assert_eq!(cell.get("count").unwrap().as_i64(), Some(4));
    router.shutdown();
}

#[test]
fn wrong_prompt_length_rejected_at_admission() {
    let router = start_router();
    let mut req = valid_request(Method::Cdlm);
    req.prompt_ids.truncate(10);
    let err = router.submit(req).err().expect("must reject");
    assert!(err.to_string().contains("padded"), "{err}");
    router.shutdown();
}

#[test]
fn unknown_backbone_rejected_at_admission() {
    let router = start_router();
    let mut req = valid_request(Method::Cdlm);
    req.backbone = "gpt-oss".into();
    let err = router.submit(req).err().expect("must reject");
    assert!(err.to_string().contains("unknown backbone"), "{err}");
    router.shutdown();
}

#[test]
fn health_reports_worker_state() {
    let router = start_router();
    let h = router.health().unwrap();
    assert_eq!(h.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(h.get("platform").unwrap().as_str(), Some("cpu"));
    // continuous-batching surface: lane/admission state is always
    // present, zeroed on an idle worker
    for k in [
        "in_flight_lanes",
        "active_batches",
        "total_admissions",
        "mid_flight_admissions",
        "retired_early",
        "aborted_queued",
        "aborted_inflight",
    ] {
        assert!(h.get(k).and_then(|v| v.as_f64()).is_some(), "missing {k}");
    }
    router.shutdown();
}

/// The continuous-batching headline: a request that arrives while a
/// batch is mid-decode is admitted into a freed lane at a block
/// boundary and completes without waiting for the prior group to
/// drain. The step delay widens each block so the second submission
/// deterministically lands mid-flight (vanilla decodes every block —
/// no early stop — so the first request is always still running).
#[test]
fn request_admitted_mid_decode_completes() {
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            max_queue: 16,
            pool_capacity: 16,
            step_delay: Duration::from_millis(40),
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let handle_a = router.submit(valid_request(Method::Vanilla)).unwrap();
    // wait until A's batch is actually in flight
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let h = router.health().unwrap();
        let lanes = h.get("in_flight_lanes").unwrap().as_f64().unwrap();
        if lanes >= 1.0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "first request never entered a batch"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    let handle_b = router.submit(valid_request(Method::Vanilla)).unwrap();
    let resp_b = handle_b.wait().expect("mid-decode admission decodes");
    let resp_a = handle_a.wait().expect("in-flight lane unaffected");
    assert!(resp_a.gen_len <= router.geometry.gen_len);
    assert!(resp_b.gen_len <= router.geometry.gen_len);
    let h = router.health().unwrap();
    let mid = h.get("mid_flight_admissions").unwrap().as_f64().unwrap();
    assert!(
        mid >= 1.0,
        "second request joined a fresh batch instead of the in-flight one"
    );
    let retired = h.get("retired_early").unwrap().as_f64().unwrap();
    assert!(
        retired >= 1.0,
        "the first-finished lane should retire while the other still runs"
    );
    router.shutdown();
}

#[test]
fn shutdown_delivers_terminal_events() {
    // satellite: shutdown must never answer a request by silently
    // dropping its channel — every request still in the system gets a
    // terminal event. A request may win the race and finish normally;
    // one caught by the drain gets Aborted{reason: "shutdown"}.
    let router = start_router();
    let handle = router.submit(valid_request(Method::Ar)).unwrap();
    router.shutdown();
    match handle.wait() {
        Ok(resp) => assert!(resp.steps >= 1, "finished before the drain"),
        Err(reason) => {
            assert!(reason.contains("shutdown"), "unexpected abort: {reason}")
        }
    }
}

#[test]
fn tau_override_travels_with_request() {
    let router = start_router();
    let mut req = valid_request(Method::Cdlm);
    req.tau_conf = Some(0.0); // finalize whole blocks per step
    let handle = router.submit(req).unwrap();
    let resp = handle.wait().unwrap();
    // tau=0 finalizes a whole block per step: steps <= num blocks + eos
    assert!(
        resp.steps <= router.geometry.num_blocks() as u64,
        "tau=0 must finalize a block per step (got {} steps)",
        resp.steps
    );
    router.shutdown();
}
