//! Integration tests over the full runtime. They run hermetically on
//! the reference backend when no artifacts directory exists; the
//! python-golden parity tests additionally require `make artifacts`
//! and skip gracefully without it.
//!
//! The load-bearing ones:
//!  * decode parity: rust engines reproduce the python reference
//!    decoders token-for-token (golden/decode_parity.json);
//!  * approx-cache anchor: dLLM-Cache with refresh_every=1 equals the
//!    vanilla top-1 decode (a fully-refreshed approximate cache is
//!    exact) — this holds on every backend by construction;
//!  * structural invariants (early stop, KV pool balance, batched ==
//!    solo) that must hold regardless of backend.

use cdlm::coordinator::methods::cached_teacher::{self, Variant};
use cdlm::coordinator::{
    DecodeOpts, GroupKey, KvPool, Method, ServingCore,
};
use cdlm::runtime::Programs;
use cdlm::tokenizer::{Tokenizer, EOS};
use cdlm::util::json::{self, Json};
use cdlm::workload::{self, Family};

fn core() -> Option<ServingCore> {
    // loads the AOT artifacts when present, else the reference backend
    Some(ServingCore::load(&cdlm::artifacts_dir(), 16).expect("core loads"))
}

fn golden(name: &str) -> Option<Json> {
    let p = cdlm::artifacts_dir().join("golden").join(name);
    p.exists().then(|| json::load(&p).expect("golden parses"))
}

#[test]
fn tokenizer_golden_parity() {
    let Some(g) = golden("tokenizer.json") else { return };
    let tok = Tokenizer::new();
    for case in g.req("cases").unwrap().as_arr().unwrap() {
        let text = case.get("text").unwrap().as_str().unwrap();
        let ids = case.get("ids").unwrap().as_i32_vec().unwrap();
        assert_eq!(tok.encode(text).unwrap(), ids, "python/rust drift: {text}");
    }
}

#[test]
fn task_generator_golden_parity() {
    let Some(g) = golden("tasks.json") else { return };
    for fam in workload::FAMILIES {
        let pinned = g.req(fam.name()).unwrap().as_arr().unwrap();
        let ours = workload::generate(fam, pinned.len(), 0xBEEF);
        for (p, o) in pinned.iter().zip(&ours) {
            assert_eq!(p.get("prompt").unwrap().as_str().unwrap(), o.prompt);
            assert_eq!(p.get("answer").unwrap().as_str().unwrap(), o.answer);
            assert_eq!(
                p.get("final").unwrap().as_str().unwrap(),
                o.final_answer
            );
        }
    }
}

/// The decode-parity goldens were produced by the python build path and
/// only bind the PJRT backend; the reference backend has its own trace
/// goldens in tests/reference_backend.rs.
fn pjrt_core() -> Option<ServingCore> {
    let core = core()?;
    if core.rt.backend_name() != "pjrt" {
        eprintln!("skipping: decode parity golden requires the pjrt backend");
        return None;
    }
    Some(core)
}

fn parity_prompts(fix: &Json) -> Vec<Vec<i32>> {
    fix.req("prompts")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_i32_vec().unwrap())
        .collect()
}

#[test]
fn vanilla_decode_matches_python_reference() {
    let Some(mut core) = pjrt_core() else { return };
    let Some(fix) = golden("decode_parity.json") else { return };
    let prompts = parity_prompts(&fix);
    let opts = DecodeOpts::defaults(&core.rt.manifest.geometry.clone());
    let key = GroupKey::new("dream", Method::Vanilla);
    let outs = core.decode_group(&key, &prompts, &opts).unwrap();
    let want_ids = fix.req("vanilla_ids").unwrap().as_arr().unwrap();
    let want_steps = fix.req("vanilla_steps").unwrap().as_i32_vec().unwrap();
    for (r, o) in outs.iter().enumerate() {
        assert_eq!(
            o.gen,
            want_ids[r].as_i32_vec().unwrap(),
            "vanilla decode diverged from python reference at row {r}"
        );
        assert_eq!(o.steps as i32, want_steps[r]);
    }
}

#[test]
fn cdlm_decode_matches_python_reference() {
    let Some(mut core) = pjrt_core() else { return };
    let Some(fix) = golden("decode_parity.json") else { return };
    let prompts = parity_prompts(&fix);
    let opts = DecodeOpts::defaults(&core.rt.manifest.geometry.clone());
    let key = GroupKey::new("dream", Method::Cdlm);
    let outs = core.decode_group(&key, &prompts, &opts).unwrap();
    let want_ids = fix.req("cdlm_ids").unwrap().as_arr().unwrap();
    let want_steps = fix.req("cdlm_steps").unwrap().as_i32_vec().unwrap();
    for (r, o) in outs.iter().enumerate() {
        assert_eq!(
            o.gen,
            want_ids[r].as_i32_vec().unwrap(),
            "CDLM decode diverged from python reference at row {r}"
        );
        assert_eq!(o.steps as i32, want_steps[r], "step count drift row {r}");
    }
}

#[test]
fn ar_decode_matches_python_reference() {
    let Some(mut core) = pjrt_core() else { return };
    let Some(fix) = golden("decode_parity.json") else { return };
    let prompts = parity_prompts(&fix);
    let opts = DecodeOpts::defaults(&core.rt.manifest.geometry.clone());
    let key = GroupKey::new("dream", Method::Ar);
    let outs = core.decode_group(&key, &prompts, &opts).unwrap();
    let want_ids = fix.req("ar_ids").unwrap().as_arr().unwrap();
    for (r, o) in outs.iter().enumerate() {
        let want = want_ids[r].as_i32_vec().unwrap();
        // python pads the tail with <pad>, rust leaves <mask>; compare
        // through the first <eos> (the generated content)
        let end = want
            .iter()
            .position(|&t| t == EOS)
            .map(|i| i + 1)
            .unwrap_or(want.len());
        assert_eq!(&o.gen[..end], &want[..end], "AR diverged at row {r}");
    }
}

#[test]
fn dllm_cache_with_refresh_every_step_equals_vanilla() {
    let Some(mut core) = core() else { return };
    let samples = workload::generate(Family::ListOp, 2, 7);
    let geom = core.rt.manifest.geometry.clone();
    let prompts: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                Family::ListOp,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect();
    let mut opts = DecodeOpts::defaults(&geom);
    opts.refresh_every = 1; // fully refreshed approx cache == exact
    let vanilla = core
        .decode_group(
            &GroupKey::new("dream", Method::Vanilla),
            &prompts,
            &opts,
        )
        .unwrap();
    let cached = core
        .decode_group(
            &GroupKey::new("dream", Method::DllmCache),
            &prompts,
            &opts,
        )
        .unwrap();
    for (v, c) in vanilla.iter().zip(&cached) {
        assert_eq!(v.gen, c.gen, "refresh_every=1 must reproduce vanilla");
    }
}

#[test]
fn batched_equals_sequential_cdlm() {
    let Some(mut core) = core() else { return };
    let geom = core.rt.manifest.geometry.clone();
    let samples = workload::generate(Family::ListOp, 2, 21);
    let prompts: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                Family::ListOp,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect();
    let opts = DecodeOpts::defaults(&geom);
    let key = GroupKey::new("dream", Method::Cdlm);
    let batched = core.decode_group(&key, &prompts, &opts).unwrap();
    let solo0 = core.decode_group(&key, &prompts[..1], &opts).unwrap();
    let solo1 = core.decode_group(&key, &prompts[1..], &opts).unwrap();
    assert_eq!(batched[0].gen, solo0[0].gen, "lane 0 batch!=solo");
    assert_eq!(batched[1].gen, solo1[0].gen, "lane 1 batch!=solo");
}

#[test]
fn early_stop_never_decodes_past_eos_block() {
    let Some(mut core) = core() else { return };
    let geom = core.rt.manifest.geometry.clone();
    let samples = workload::generate(Family::ListOp, 4, 33);
    let prompts: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                Family::ListOp,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect();
    let opts = DecodeOpts::defaults(&geom);
    let key = GroupKey::new("dream", Method::Cdlm);
    let outs = core.decode_group(&key, &prompts, &opts).unwrap();
    for o in outs {
        if let Some(eos_at) = o.gen.iter().position(|&t| t == EOS) {
            let blk_end =
                (eos_at / geom.block_size + 1) * geom.block_size;
            // everything after the eos block must still be <mask>
            for &t in &o.gen[blk_end..] {
                assert_eq!(
                    t,
                    cdlm::tokenizer::MASK,
                    "decoded past the early-stop boundary"
                );
            }
        }
    }
}

#[test]
fn kv_pool_is_balanced_after_decoding() {
    let Some(mut core) = core() else { return };
    let geom = core.rt.manifest.geometry.clone();
    let prompts: Vec<Vec<i32>> = workload::generate(Family::ListOp, 3, 5)
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                Family::ListOp,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect();
    let opts = DecodeOpts::defaults(&geom);
    for m in [Method::Cdlm, Method::Ar, Method::FastDllmDc, Method::DllmCache]
    {
        let key = GroupKey::new("dream", m);
        core.decode_group(&key, &prompts, &opts).unwrap();
        assert_eq!(core.pool.in_use(), 0, "{} leaked KV slots", m.name());
    }
    assert!(core.pool.peak_in_use > 0);
}

#[test]
fn dual_cache_decode_runs_and_respects_structure() {
    let Some(mut core) = core() else { return };
    let geom = core.rt.manifest.geometry.clone();
    let prompts: Vec<Vec<i32>> = workload::generate(Family::ChainArith, 2, 9)
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect();
    // exercise the engine directly for structural assertions
    let weights =
        cdlm::runtime::ModelWeights::load(&core.rt.manifest, "teacher_dream")
            .unwrap();
    let progs = Programs::new(&core.rt, &weights);
    let mut pool = KvPool::new(&geom, 4);
    let opts = DecodeOpts::defaults(&geom);
    let lanes: Vec<&[i32]> = prompts.iter().map(Vec::as_slice).collect();
    let outs = cached_teacher::decode(
        &progs,
        &geom,
        &opts,
        &lanes,
        &mut pool,
        Variant::DualCache,
    )
    .unwrap();
    for o in &outs {
        // thresholded parallel finalization: fewer steps than positions
        assert!(o.steps <= geom.gen_len as u64);
        assert!(o.steps >= geom.num_blocks() as u64);
        // everything finalized (no early stop in the teacher baselines)
        assert!(o.gen.iter().all(|&t| t != cdlm::tokenizer::MASK));
    }
    assert_eq!(pool.in_use(), 0);
}

#[test]
fn fig8_sweep_blocks_have_programs() {
    let Some(core) = core() else { return };
    for &b in &core.rt.manifest.sweep_blocks.clone() {
        assert!(
            core.rt
                .manifest
                .find_program("student_block_step", 1, Some(b))
                .is_some(),
            "missing sweep program B={b}"
        );
    }
}
