//! Sharded serving core: prefix-affinity routing, work stealing,
//! admission control (queue depth + per-client fairness), graceful
//! drain, and the shard-count invariance contract — a request decoded
//! closed-loop (solo cohort) produces byte-identical text and
//! step/model-call accounting whether the dispatcher ran 1 replica
//! or 4.
//!
//! Runs hermetically on the deterministic reference backend.

use std::time::Duration;

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::{GenerateRequest, Method, Router};
use cdlm::server::http::encode_user_prompt;
use cdlm::tokenizer::Tokenizer;
use cdlm::util::json::Json;
use cdlm::workload::{self, Family};

fn request_for(prompt: &str, method: Method) -> GenerateRequest {
    let tok = Tokenizer::new();
    GenerateRequest::new(
        "dream",
        method,
        encode_user_prompt(&tok, prompt, 64).unwrap(),
    )
}

fn sample_prompts(n: usize, seed: u64) -> Vec<String> {
    workload::generate(Family::ListOp, n, seed)
        .into_iter()
        .map(|s| s.prompt)
        .collect()
}

/// Sum a numeric per-shard counter out of `health()["shards"]`.
fn shard_counter(health: &Json, key: &str) -> Vec<u64> {
    health
        .get("shards")
        .and_then(Json::as_arr)
        .expect("health carries the per-shard breakdown")
        .iter()
        .map(|s| s.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64)
        .collect()
}

#[test]
fn queue_overflow_is_a_429_with_a_retry_after_hint() {
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 1,
            max_active: 1,
            max_queue: 1,
            pool_capacity: 4,
            step_delay: Duration::from_millis(50),
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let prompts = sample_prompts(3, 0x51);
    // A is popped off the queue and decodes slowly; B fills the single
    // queue slot; C must bounce at admission
    let a = router.submit(request_for(&prompts[0], Method::Cdlm)).unwrap();
    std::thread::sleep(Duration::from_millis(150));
    let b = router.submit(request_for(&prompts[1], Method::Cdlm)).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    let err = router
        .submit(request_for(&prompts[2], Method::Cdlm))
        .err()
        .expect("third submit must be refused");
    assert_eq!(err.status(), 429, "{err}");
    assert!(err.retry_after().is_some(), "429 must carry a retry hint");
    assert!(err.to_string().contains("queue full"), "{err}");
    let h = router.health().unwrap();
    assert_eq!(
        h.get("rejected_queue_full").and_then(Json::as_f64),
        Some(1.0)
    );
    a.cancel();
    b.cancel();
    router.shutdown();
}

#[test]
fn per_client_cap_rejects_the_flooder_but_not_others() {
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 1,
            max_active: 1,
            max_queue: 32,
            pool_capacity: 4,
            max_per_client: 2,
            step_delay: Duration::from_millis(50),
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let prompts = sample_prompts(4, 0x52);
    let submit = |i: usize, client: &str| {
        let mut req = request_for(&prompts[i], Method::Cdlm);
        req.client = Some(client.into());
        router.submit(req)
    };
    let a = submit(0, "flood").expect("first under the cap");
    let b = submit(1, "flood").expect("second under the cap");
    let err = submit(2, "flood").err().expect("third must hit the cap");
    assert_eq!(err.status(), 429, "{err}");
    assert!(err.retry_after().is_some(), "cap refusal carries a hint");
    assert!(err.to_string().contains("flood"), "{err}");
    // fairness: the flooder's saturation must not starve other clients
    let c = submit(3, "polite").expect("other clients still admitted");
    let h = router.health().unwrap();
    assert_eq!(
        h.get("rejected_client_cap").and_then(Json::as_f64),
        Some(1.0)
    );
    for handle in [&a, &b, &c] {
        handle.cancel();
    }
    router.shutdown();
}

#[test]
fn graceful_drain_answers_every_request_across_replicas() {
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 1,
            max_active: 1,
            max_queue: 32,
            pool_capacity: 8,
            replicas: 2,
            step_delay: Duration::from_millis(20),
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let prompts = sample_prompts(6, 0x53);
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| router.submit(request_for(p, Method::Cdlm)).unwrap())
        .collect();
    // let both shards pull one request into decode before draining
    std::thread::sleep(Duration::from_millis(100));
    router.begin_drain();
    // drain refuses new work with a 503
    let err = router
        .submit(request_for(&prompts[0], Method::Cdlm))
        .err()
        .expect("submit during drain must be refused");
    assert_eq!(err.status(), 503, "{err}");
    assert!(err.retry_after().is_some(), "503 must carry a retry hint");
    // the drain contract: every request already in the system gets its
    // terminal event — in-flight lanes finish, queued ones abort with
    // "shutdown", and no channel is ever silently dropped
    let mut finished = 0;
    let mut aborted = 0;
    for h in handles {
        match h.wait() {
            Ok(resp) => {
                assert!(!resp.gen_ids.is_empty());
                finished += 1;
            }
            Err(reason) => {
                assert!(
                    reason.contains("shutdown"),
                    "queued work must abort with the drain reason, \
                     not {reason:?}"
                );
                aborted += 1;
            }
        }
    }
    assert_eq!(finished + aborted, 6, "no request may vanish");
    assert!(finished >= 1, "in-flight lanes must finish, not abort");
    assert!(aborted >= 1, "queued lanes must abort at drain");
    router.join();
}

#[test]
fn repeated_prompts_route_to_the_warm_affinity_shard() {
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 2,
            max_queue: 64,
            pool_capacity: 16,
            replicas: 4,
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let prompt = sample_prompts(1, 0x54).pop().unwrap();
    // closed loop: the queue is empty at each submit, so the affinity
    // shard is never over its fair share and no request spills
    for _ in 0..6 {
        let h = router.submit(request_for(&prompt, Method::Cdlm)).unwrap();
        h.wait().expect("decode ok");
    }
    let h = router.health().unwrap();
    assert_eq!(h.get("routed_affinity").and_then(Json::as_f64), Some(6.0));
    assert_eq!(h.get("routed_spill").and_then(Json::as_f64), Some(0.0));
    let admitted = shard_counter(&h, "admitted_requests");
    assert_eq!(admitted.iter().sum::<u64>(), 6);
    assert_eq!(
        admitted.iter().filter(|&&n| n > 0).count(),
        1,
        "one warm shard must own every repeat of the prompt: {admitted:?}"
    );
    let affinity = shard_counter(&h, "affinity_admissions");
    assert_eq!(affinity, admitted, "every admission was affinity-routed");
    // the warm shard's prefix trie served the repeats
    let hits = shard_counter(&h, "prefix_hits");
    assert!(
        hits.iter().sum::<u64>() >= 1,
        "repeated prompt must hit the warm prefix trie: {hits:?}"
    );
    router.shutdown();
}

#[test]
fn stolen_request_produces_a_byte_identical_trace() {
    // solo baseline: one replica, cohort of one
    let solo = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 1,
            max_active: 1,
            max_queue: 8,
            pool_capacity: 4,
            prefix_cache: false,
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let prompt = sample_prompts(1, 0x55).pop().unwrap();
    let want = solo
        .submit(request_for(&prompt, Method::Cdlm))
        .unwrap()
        .wait()
        .expect("solo decode ok");
    solo.shutdown();

    // two shards, per-shard capacity of one, slow decode: both requests
    // affinity-route to the same shard, so the idle sibling must steal
    // the queued one once it has aged past the batching window
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 1,
            max_active: 1,
            max_wait: Duration::from_millis(5),
            max_queue: 8,
            pool_capacity: 8,
            replicas: 2,
            prefix_cache: false,
            step_delay: Duration::from_millis(30),
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let a = router.submit(request_for(&prompt, Method::Cdlm)).unwrap();
    std::thread::sleep(Duration::from_millis(60));
    let b = router.submit(request_for(&prompt, Method::Cdlm)).unwrap();
    let resp_a = a.wait().expect("first decode ok");
    let resp_b = b.wait().expect("stolen decode ok");
    let h = router.health().unwrap();
    let stolen: u64 = shard_counter(&h, "stolen").iter().sum();
    assert!(stolen >= 1, "the idle sibling must have stolen: {h}");
    // the theft is invisible in the decode trace: token ids, text, and
    // step/model-call accounting are byte-identical to the solo run
    for resp in [&resp_a, &resp_b] {
        assert_eq!(resp.gen_ids, want.gen_ids);
        assert_eq!(resp.text, want.text);
        assert_eq!(resp.steps, want.steps);
        assert_eq!(resp.model_calls, want.model_calls);
    }
    router.shutdown();
}

#[test]
fn solo_accounting_is_invariant_across_replica_counts() {
    let prompts = sample_prompts(3, 0x56);
    let run = |replicas: usize| {
        let router = Router::start(
            cdlm::artifacts_dir(),
            RouterConfig {
                replicas,
                prefix_cache: false,
                ..RouterConfig::default()
            },
        )
        .expect("router starts");
        let mut out = Vec::new();
        for p in &prompts {
            for method in [Method::Cdlm, Method::Vanilla] {
                let resp = router
                    .submit(request_for(p, method))
                    .unwrap()
                    .wait()
                    .expect("decode ok");
                out.push((
                    resp.text,
                    resp.gen_ids,
                    resp.steps,
                    resp.model_calls,
                ));
            }
        }
        router.shutdown();
        out
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(
        one, four,
        "closed-loop decode traces and accounting must not depend on \
         the replica count"
    );
}
