//! Hot-path contracts: (1) the counting allocator actually counts —
//! an injected allocation moves the counter, a pure-arithmetic window
//! does not — and (2) reusing one machine's [`StepScratch`] arena
//! across rounds with *different batch shapes* leaves decode traces
//! byte-identical to a fresh machine's. The second is the correctness
//! contract behind the allocation-free hot path: arena buffers are
//! overwritten, never trusted to be clean, so a dirty arena must be
//! invisible in the output.
//!
//! This test binary installs [`CountingAlloc`] as its global allocator
//! (the library and the other test binaries do not), mirroring the
//! `cdlm` CLI so the counter tests exercise the exact gate mechanism
//! `bench --scenario hotpath` uses.

use std::sync::Arc;

use cdlm::coordinator::{
    BatchState, DecodeOpts, DecodeOutcome, ALL_METHODS,
};
use cdlm::hotpath;
use cdlm::runtime::{ModelWeights, Runtime};
use cdlm::util::alloc_count::{self, CountingAlloc};

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

const SEED: u64 = 0x5EED_0008;

/// Admit every prompt, then run the machine dry, returning outcomes in
/// admission order.
fn drive(machine: &mut BatchState, prompts: &[Vec<i32>]) -> Vec<DecodeOutcome> {
    let lane_of: Vec<usize> = prompts
        .iter()
        .map(|p| machine.admit(p, None).expect("admit"))
        .collect();
    let mut outs: Vec<Option<DecodeOutcome>> = vec![None; prompts.len()];
    let mut guard = 0;
    while !machine.is_empty() {
        machine.step_cycle().expect("step_cycle");
        for (lane, o) in machine.take_finished() {
            let req = lane_of
                .iter()
                .position(|&l| l == lane)
                .expect("finished lane was admitted");
            assert!(outs[req].is_none(), "lane finished twice");
            outs[req] = Some(o);
        }
        guard += 1;
        assert!(guard < 10_000, "machine did not drain");
    }
    outs.into_iter()
        .map(|o| o.expect("every admission finished"))
        .collect()
}

fn assert_same_trace(a: &[DecodeOutcome], b: &[DecodeOutcome], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: outcome count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.gen, y.gen, "{ctx}[{i}]: generated tokens");
        assert_eq!(x.steps, y.steps, "{ctx}[{i}]: steps");
        assert_eq!(x.model_calls, y.model_calls, "{ctx}[{i}]: model_calls");
        assert_eq!(x.gen_len, y.gen_len, "{ctx}[{i}]: gen_len");
    }
}

#[test]
fn counter_detects_injected_allocation() {
    assert!(
        alloc_count::counting_enabled(),
        "this test binary must have CountingAlloc installed"
    );
    // an injected heap allocation moves the thread counter
    let before = alloc_count::thread_allocs();
    let v: Vec<u64> = std::hint::black_box((0..64).collect());
    assert!(
        alloc_count::thread_allocs() > before,
        "allocation went uncounted — the hotpath gate would be vacuous"
    );
    drop(v);
    // frees don't count, and an allocation-free window reads zero delta
    // — exactly what the bench asserts about steady-state decode steps
    let flat = alloc_count::thread_allocs();
    let mut acc = 0u64;
    for i in 0..10_000u64 {
        acc = acc.wrapping_mul(31).wrapping_add(std::hint::black_box(i));
    }
    std::hint::black_box(acc);
    assert_eq!(
        alloc_count::thread_allocs(),
        flat,
        "pure-arithmetic window must not move the counter"
    );
    assert!(alloc_count::process_allocs() >= alloc_count::thread_allocs());
}

#[test]
fn dirty_arena_reuse_is_trace_identical_across_batch_shapes() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    // three rounds through ONE machine with different batch shapes:
    // 4 lanes (bucket 4) -> 2 lanes (bucket 2) -> 3 lanes (bucket 4
    // again) — the shared arena shrinks and regrows with stale data
    // from earlier rounds in every buffer
    let round_a: Vec<Vec<i32>> =
        (0..4).map(|l| hotpath::synth_prompt(&geom, l)).collect();
    let round_b: Vec<Vec<i32>> =
        (10..12).map(|l| hotpath::synth_prompt(&geom, l)).collect();
    let round_c: Vec<Vec<i32>> =
        (20..23).map(|l| hotpath::synth_prompt(&geom, l)).collect();

    for m in ALL_METHODS {
        let weights = Arc::new(
            ModelWeights::load(&rt.manifest, &m.weights_for("dream"))
                .expect("weights"),
        );
        let mut dirty = BatchState::new(
            rt.clone(),
            weights.clone(),
            m,
            opts.clone(),
            4,
        )
        .expect("machine");
        let got_a = drive(&mut dirty, &round_a);
        let got_b = drive(&mut dirty, &round_b);
        let got_c = drive(&mut dirty, &round_c);

        for (prompts, got, tag) in [
            (&round_a, &got_a, "A(4)"),
            (&round_b, &got_b, "B(2)"),
            (&round_c, &got_c, "C(3)"),
        ] {
            let mut fresh = BatchState::new(
                rt.clone(),
                weights.clone(),
                m,
                opts.clone(),
                4,
            )
            .expect("machine");
            let want = drive(&mut fresh, prompts);
            assert_same_trace(
                got,
                &want,
                &format!("{} round {}", m.name(), tag),
            );
        }
    }
}

/// The bench gate itself, at test scale: steady-state gated windows of
/// every method must perform zero heap allocations. Ignored in the
/// default run — `cdlm bench --scenario hotpath` (CI's `make hotpath`)
/// is the gating entry point; run explicitly with
/// `cargo test --test hot_path -- --ignored` for a local check.
#[test]
#[ignore = "gated in CI via `make hotpath`; run with --ignored locally"]
fn steady_state_steps_allocate_nothing() {
    assert!(alloc_count::counting_enabled());
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let mut buckets = rt.manifest.buckets.clone();
    buckets.sort_unstable();
    for m in ALL_METHODS {
        let weights =
            ModelWeights::load(&rt.manifest, &m.weights_for("dream"))
                .expect("weights");
        let progs = cdlm::runtime::Programs::new(&rt, &weights);
        for bs in [1usize, 4] {
            let cell =
                hotpath::run_cell(&progs, &geom, &buckets, m, bs, 3, 0.9)
                    .expect("cell");
            assert_eq!(
                cell.steady_allocs,
                0,
                "{} bs={}: steady-state step allocated",
                m.name(),
                bs
            );
        }
    }
}
