//! Shared-prefix KV cache invariants.
//!
//! The load-bearing pins of the prefix-chain refactor:
//!  * **warm == cold trace identity** — for every method, re-admitting
//!    a prompt whose chain is cached decodes byte-identically (gen ids,
//!    steps, gen lengths) to the cold admission, with `model_calls`
//!    lower by exactly the skipped prefill (and only for the methods
//!    that prefill at admission: CDLM and AR);
//!  * **refcount pin/unpin under mid-batch retirement** — lanes sharing
//!    a chain pin it once each; a lane retiring mid-batch unpins
//!    without perturbing the survivor, and the drained machine retains
//!    the chain as warm cache;
//!  * **copy-on-write divergence** — a prompt diverging at block `k`
//!    reuses exactly `k` cached blocks and branches the trie; its
//!    decode equals the solo cold trace;
//!  * **eviction safety** — pressure never reclaims a pinned chain
//!    (covered at pool granularity in `kv_cache.rs` unit tests; the
//!    router-level test here closes the serving loop via `/healthz`).

use std::sync::Arc;

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::{
    BatchState, DecodeOpts, DecodeOutcome, Engine, GenerateRequest, KvPool,
    Method, Router, ALL_METHODS,
};
use cdlm::runtime::{ModelWeights, Runtime};
use cdlm::server::http::encode_user_prompt;
use cdlm::tokenizer::Tokenizer;
use cdlm::util::prop::check;
use cdlm::workload::{self, Family};

const SEED: u64 = 0x5EED_0004;

fn prompts(n: usize, task_seed: u64) -> Vec<Vec<i32>> {
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let tok = Tokenizer::new();
    workload::generate(Family::ChainArith, n, task_seed)
        .iter()
        .map(|s| {
            workload::encode_example(
                &tok,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect()
}

fn weights_for(rt: &Runtime, m: Method) -> Arc<ModelWeights> {
    Arc::new(
        ModelWeights::load(&rt.manifest, &m.weights_for("dream")).unwrap(),
    )
}

fn machine(
    rt: &Arc<Runtime>,
    m: Method,
    opts: &DecodeOpts,
    capacity: usize,
    prefix: bool,
) -> BatchState {
    let mut st = BatchState::new(
        rt.clone(),
        weights_for(rt, m),
        m,
        opts.clone(),
        capacity,
    )
    .unwrap();
    st.set_prefix_cache(prefix);
    st
}

/// Admit `prompts` into a (possibly warm) machine and drive it to
/// drain, returning outcomes in admission order.
fn run_pass(st: &mut BatchState, prompts: &[Vec<i32>]) -> Vec<DecodeOutcome> {
    let mut lanes = Vec::new();
    for p in prompts {
        lanes.push(st.admit(p, None).unwrap());
    }
    let mut out: Vec<Option<DecodeOutcome>> = Vec::new();
    out.resize_with(prompts.len(), || None);
    let mut guard = 0;
    while !st.is_empty() {
        guard += 1;
        assert!(guard <= 10_000, "machine failed to drain");
        st.step_cycle().unwrap();
        for (lane, o) in st.take_finished() {
            let req = lanes
                .iter()
                .position(|&l| l == lane)
                .expect("retired lane was admitted");
            assert!(out[req].is_none(), "lane retired twice");
            out[req] = Some(o);
        }
    }
    out.into_iter().map(Option::unwrap).collect()
}

fn same_trace(a: &DecodeOutcome, b: &DecodeOutcome) -> bool {
    a.gen == b.gen && a.steps == b.steps && a.gen_len == b.gen_len
}

/// Does this method run a prefill model call at machine admission (the
/// call a warm hit skips)?
fn prefills_at_admit(m: Method) -> bool {
    matches!(m, Method::Cdlm | Method::Ar)
}

#[test]
fn warm_equals_cold_with_one_less_prefill_for_all_methods() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(1, 0xAB01);
    for m in ALL_METHODS {
        // closed-batch cold reference (always cold by construction)
        let weights = weights_for(&rt, m);
        let engine = Engine::new(&rt, &weights);
        let mut pool = KvPool::new(&geom, 4);
        let closed = engine.decode_serial(m, &opts, &ps, &mut pool).unwrap();

        let mut st = machine(&rt, m, &opts, 1, true);
        let cold = run_pass(&mut st, &ps);
        let warm = run_pass(&mut st, &ps);

        assert!(
            same_trace(&cold[0], &closed[0]),
            "{}: cold machine trace diverged from closed batch",
            m.name()
        );
        assert!(
            same_trace(&warm[0], &cold[0]),
            "{}: warm-hit decode trace diverged from cold",
            m.name()
        );
        if prefills_at_admit(m) {
            assert_eq!(
                warm[0].model_calls + 1,
                cold[0].model_calls,
                "{}: warm hit must save exactly the prefill call",
                m.name()
            );
            assert_eq!(st.prefix_hits(), 1, "{}", m.name());
            assert!(st.kv_shared_pages() > 0, "{}", m.name());
        } else {
            assert_eq!(
                warm[0].model_calls,
                cold[0].model_calls,
                "{}: non-prefill methods must be unaffected",
                m.name()
            );
            assert_eq!(st.prefix_hits(), 0, "{}", m.name());
        }
        assert_eq!(st.kv_in_use(), 0, "{} leaked KV slots", m.name());
    }
}

#[test]
fn property_warm_trace_identical_to_cold_across_methods() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    check("prefix-warm-equals-cold", 12, |r| {
        let n = 1 + r.index(3);
        let m = ALL_METHODS[r.index(ALL_METHODS.len())];
        let ps =
            prompts(n, 0xF00 ^ (n as u64) << 8 ^ r.index(1024) as u64);
        let mut st = machine(&rt, m, &opts, n, true);
        let cold = run_pass(&mut st, &ps);
        let warm = run_pass(&mut st, &ps);
        // gen/steps identical per lane; model_calls never higher warm
        // (duplicate prompts may already hit inside the cold pass, so
        // the exact -1 delta is pinned in the solo test above)
        cold.iter().zip(&warm).all(|(c, w)| {
            same_trace(c, w) && w.model_calls <= c.model_calls
        }) && st.kv_in_use() == 0
    });
}

#[test]
fn refcounts_pin_and_unpin_under_mid_batch_retirement() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let p = prompts(1, 0x77AA).pop().unwrap();
    let blocks = geom.prompt_len / geom.block_size;

    // solo cold reference (prefix off)
    let mut solo_st = machine(&rt, Method::Cdlm, &opts, 1, false);
    let solo = run_pass(&mut solo_st, std::slice::from_ref(&p));

    let mut st = machine(&rt, Method::Cdlm, &opts, 2, true);
    let _lane_a = st.admit(&p, None).unwrap();
    assert_eq!(
        st.prefix_chain_info(&p),
        Some((blocks, 1)),
        "admission installs and pins the full chain"
    );
    st.step_cycle().unwrap();
    // A may have early-stopped within its first block
    let mut finished = st.take_finished();
    let lane_b = st.admit(&p, None).unwrap();
    assert_eq!(st.prefix_hits(), 1, "B re-admitted the cached prompt");
    let live = if finished.is_empty() { 2 } else { 1 };
    assert_eq!(
        st.prefix_chain_info(&p),
        Some((blocks, live)),
        "each live lane holds exactly one pin"
    );
    let mut got_b = None;
    let mut guard = 0;
    while !st.is_empty() {
        guard += 1;
        assert!(guard <= 10_000);
        st.step_cycle().unwrap();
        for (lane, o) in st.take_finished() {
            if lane == lane_b && got_b.is_none() {
                got_b = Some(o);
            } else {
                finished.push((lane, o));
            }
        }
    }
    let got_b = got_b.expect("lane B retired");
    assert!(
        same_trace(&got_b, &solo[0]),
        "warm shared-chain decode diverged from the solo cold trace"
    );
    assert_eq!(
        got_b.model_calls + 1,
        solo[0].model_calls,
        "warm hit saves exactly the prefill call"
    );
    // fully drained: unpinned but retained as warm cache
    assert_eq!(st.prefix_chain_info(&p), Some((blocks, 0)));
    assert_eq!(st.kv_shared_pages(), blocks);
    assert_eq!(st.kv_in_use(), 0, "machine leaked KV slots");
}

#[test]
fn copy_on_write_divergence_at_each_block_offset() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let pb = geom.block_size;
    let blocks = geom.prompt_len / pb;
    // synthetic prompts (no padding): full control over block content
    let base: Vec<i32> = vec![5; geom.prompt_len];
    for k in 0..blocks {
        let mut q = base.clone();
        q[k * pb] = 6; // diverge exactly at block k

        // solo cold reference for q
        let mut solo_st = machine(&rt, Method::Cdlm, &opts, 1, false);
        let solo = run_pass(&mut solo_st, std::slice::from_ref(&q));

        let mut st = machine(&rt, Method::Cdlm, &opts, 1, true);
        let cold_base = run_pass(&mut st, std::slice::from_ref(&base));
        let hit_blocks_before = st.prefix_hit_blocks();
        let pages_before = st.kv_shared_pages();
        let got = run_pass(&mut st, std::slice::from_ref(&q));

        assert_eq!(
            st.prefix_hit_blocks() - hit_blocks_before,
            k as u64,
            "divergence at block {k} must reuse exactly {k} blocks"
        );
        assert_eq!(
            st.kv_shared_pages() - pages_before,
            blocks - k,
            "only the divergent tail gets new pages (copy-on-write)"
        );
        assert!(
            same_trace(&got[0], &solo[0]),
            "divergent-at-{k} decode differs from its solo cold trace"
        );
        assert_eq!(
            got[0].model_calls, solo[0].model_calls,
            "partial hits still run one prefill call"
        );
        // the original chain is intact: base re-admits as a full hit
        let hits_before = st.prefix_hits();
        let warm = run_pass(&mut st, std::slice::from_ref(&base));
        assert_eq!(st.prefix_hits(), hits_before + 1);
        assert!(same_trace(&warm[0], &cold_base[0]));
        assert_eq!(warm[0].model_calls + 1, cold_base[0].model_calls);
    }
}

#[test]
fn router_repeated_prompts_hit_and_report_on_healthz() {
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 2,
            max_queue: 8,
            pool_capacity: 8,
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let tok = Tokenizer::new();
    let s = workload::generate(Family::ChainArith, 1, 99).pop().unwrap();
    let req = || {
        GenerateRequest::new(
            "dream",
            Method::Cdlm,
            encode_user_prompt(&tok, &s.prompt, 64).unwrap(),
        )
    };
    // sequential round trips: the second arrival admits against the
    // retained machine's warm chain
    let cold = router.submit(req()).unwrap().wait().unwrap();
    let warm = router.submit(req()).unwrap().wait().unwrap();
    assert_eq!(warm.gen_ids, cold.gen_ids, "warm response text identical");
    assert_eq!(warm.steps, cold.steps);
    assert_eq!(
        warm.model_calls + 1,
        cold.model_calls,
        "warm admission skipped its prefill"
    );
    let h = router.health().unwrap();
    let stat = |k: &str| {
        h.get(k)
            .and_then(cdlm::util::json::Json::as_f64)
            .unwrap_or(-1.0)
    };
    assert!(stat("prefix_hits") >= 1.0, "healthz prefix_hits: {h}");
    assert!(
        stat("prefix_hit_blocks") >= 1.0,
        "healthz prefix_hit_blocks: {h}"
    );
    assert!(stat("kv_shared_slots") >= 1.0, "healthz kv_shared_slots: {h}");
    assert!(stat("prefix_evictions") >= 0.0, "healthz prefix_evictions: {h}");
    router.shutdown();
}

#[test]
fn disabled_prefix_cache_changes_nothing() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(1, 0xD15A);
    let mut st = machine(&rt, Method::Cdlm, &opts, 1, false);
    let first = run_pass(&mut st, &ps);
    let second = run_pass(&mut st, &ps);
    assert!(same_trace(&first[0], &second[0]));
    assert_eq!(first[0].model_calls, second[0].model_calls);
    assert_eq!(st.prefix_hits(), 0);
    assert_eq!(st.kv_shared_pages(), 0, "no pages populated when off");
}
