//! Lane-event pipeline invariants: streamed block deltas, end-to-end
//! cancellation, and the KV/prefix-chain reclamation cancellation
//! promises.
//!
//! Load-bearing pins:
//!  * **byte identity** — for every method, concatenating a request's
//!    `Committed` text deltas reproduces the non-streamed response
//!    `text` byte-for-byte (router level), and the machine's
//!    `CommitRun`s reproduce the closed-batch gen ids (machine level);
//!  * **cancellation reclaims resources** — a lane cancelled at block
//!    k frees its KV slot and unpins its prefix chain (pool accounting
//!    returns to the warm-cache baseline);
//!  * **isolation** — cancelling one lane mid-batch leaves the
//!    surviving lanes' decode traces (gen ids, steps, model calls)
//!    exactly at their solo values;
//!  * **budget / deadline** — `max_new_tokens` truncates with a normal
//!    `Finished`, an expired deadline aborts without ever spending a
//!    lane, and both surface on `/healthz`.

use std::sync::Arc;
use std::time::Duration;

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::{
    BatchState, DecodeOpts, DecodeOutcome, Engine, GenerateRequest, KvPool,
    LaneEvent, Method, Router, ALL_METHODS,
};
use cdlm::runtime::{ModelWeights, Runtime};
use cdlm::server::http::encode_user_prompt;
use cdlm::tokenizer::{StreamDecoder, Tokenizer};
use cdlm::workload::{self, Family};

const SEED: u64 = 0x5EED_0007;

fn prompts(n: usize, task_seed: u64) -> Vec<Vec<i32>> {
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let tok = Tokenizer::new();
    workload::generate(Family::ChainArith, n, task_seed)
        .iter()
        .map(|s| {
            workload::encode_example(
                &tok,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect()
}

fn weights_for(rt: &Runtime, m: Method) -> Arc<ModelWeights> {
    Arc::new(
        ModelWeights::load(&rt.manifest, &m.weights_for("dream")).unwrap(),
    )
}

fn machine(
    rt: &Arc<Runtime>,
    m: Method,
    opts: &DecodeOpts,
    capacity: usize,
) -> BatchState {
    BatchState::new(
        rt.clone(),
        weights_for(rt, m),
        m,
        opts.clone(),
        capacity,
    )
    .unwrap()
}

fn request_for(method: Method, task_seed: u64) -> GenerateRequest {
    let tok = Tokenizer::new();
    let s = workload::generate(Family::ListOp, 1, task_seed).pop().unwrap();
    GenerateRequest::new(
        "dream",
        method,
        encode_user_prompt(&tok, &s.prompt, 64).unwrap(),
    )
}

// ---------------------------------------------------------------------------
// (a) stream deltas are byte-identical to the one-shot text
// ---------------------------------------------------------------------------

/// Router level: for every method, drain a request's event pipeline and
/// check `Admitted` ordering, exactly one terminal event, and the
/// concatenated `Committed` deltas equal to the final `text`.
#[test]
fn stream_deltas_concatenate_to_the_response_text_for_all_methods() {
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 2,
            max_queue: 16,
            pool_capacity: 16,
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    for m in ALL_METHODS {
        let handle = router.submit(request_for(m, 77)).unwrap();
        let mut concat = String::new();
        let mut admitted = false;
        let mut finished = None;
        let mut next_block = 0usize;
        while let Some(ev) = handle.next_event() {
            match ev {
                LaneEvent::Admitted => {
                    assert!(!admitted, "{}: double Admitted", m.name());
                    assert!(
                        concat.is_empty() && finished.is_none(),
                        "{}: Admitted out of order",
                        m.name()
                    );
                    admitted = true;
                }
                LaneEvent::Committed { block, text, .. } => {
                    assert!(admitted, "{}: delta before Admitted", m.name());
                    assert_eq!(
                        block,
                        next_block,
                        "{}: blocks out of order",
                        m.name()
                    );
                    next_block += 1;
                    concat.push_str(&text);
                }
                LaneEvent::Finished(resp) => {
                    finished = Some(resp);
                    // terminal: the channel must close without another
                    // event
                    assert!(
                        handle.next_event().is_none(),
                        "{}: event after the terminal Finished",
                        m.name()
                    );
                    break;
                }
                LaneEvent::Aborted { reason, .. } => {
                    panic!("{}: unexpected abort: {reason}", m.name())
                }
            }
        }
        let resp = finished.expect("terminal event");
        assert!(next_block >= 1, "{}: no block deltas", m.name());
        assert_eq!(
            concat,
            resp.text,
            "{}: streamed deltas diverge from the one-shot text",
            m.name()
        );
    }
    router.shutdown();
}

/// Machine level: per-lane `CommitRun`s, decoded incrementally, equal
/// the closed-batch text — and arrive in generation order.
#[test]
fn commit_runs_reproduce_closed_batch_text_for_all_methods() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let tok = Tokenizer::new();
    let ps = prompts(3, 0x57EA);
    for m in ALL_METHODS {
        let weights = weights_for(&rt, m);
        let engine = Engine::new(&rt, &weights);
        let mut pool = KvPool::new(&geom, 8);
        let closed = engine.decode_serial(m, &opts, &ps, &mut pool).unwrap();
        let mut st = machine(&rt, m, &opts, ps.len());
        for p in &ps {
            st.admit(p, None).unwrap();
        }
        let mut streams: Vec<(StreamDecoder, String, usize)> = (0..ps.len())
            .map(|_| (StreamDecoder::new(), String::new(), 0))
            .collect();
        let mut guard = 0;
        while !st.is_empty() {
            guard += 1;
            assert!(guard <= 10_000, "{}: machine failed to drain", m.name());
            for run in st.step_cycle().unwrap() {
                let (detok, text, watermark) = &mut streams[run.lane];
                assert_eq!(
                    run.start, *watermark,
                    "{}: runs must be contiguous per lane",
                    m.name()
                );
                *watermark += run.tokens.len();
                text.push_str(&tok.decode_stream(detok, &run.tokens));
            }
            st.take_finished();
        }
        for (lane, (_, text, _)) in streams.iter().enumerate() {
            let want = tok.decode(&closed[lane].gen, true);
            assert_eq!(
                text, &want,
                "{}: lane {lane} streamed text diverges",
                m.name()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// (b) cancellation frees KV slots and unpins prefix chains
// ---------------------------------------------------------------------------

#[test]
fn cancel_mid_decode_frees_kv_and_unpins_prefix_chain() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(1, 0xCA9C);
    let mut st = machine(&rt, Method::Cdlm, &opts, 1);
    st.set_prefix_cache(true);
    // warm the chain: one full decode, lane retires, chain unpinned
    let lane = st.admit(&ps[0], None).unwrap();
    let mut guard = 0;
    while !st.is_empty() {
        guard += 1;
        assert!(guard <= 10_000);
        st.step_cycle().unwrap();
        st.take_finished();
    }
    let baseline = st
        .prefix_chain_info(&ps[0])
        .expect("prefill installed a chain");
    assert_eq!(baseline.1, 0, "retired lane must leave the chain unpinned");
    assert_eq!(st.kv_in_use(), 0);

    // warm admission: chain pinned, prefill skipped
    let lane2 = st.admit(&ps[0], None).unwrap();
    assert_eq!(lane2, lane, "capacity-1 machine recycles the lane");
    assert_eq!(st.prefix_hits(), 1, "warm admission must hit the chain");
    let pinned = st.prefix_chain_info(&ps[0]).unwrap();
    assert_eq!(pinned.0, baseline.0, "resident blocks unchanged");
    assert_eq!(pinned.1, 1, "admission must pin the chain");
    assert_eq!(st.kv_in_use(), 1);

    // cancel at block k=1: the slot frees and the pin releases, but the
    // chain stays resident as warm cache
    st.step_cycle().unwrap();
    st.take_finished();
    let partial = st.cancel_lane(lane2);
    let cancelled_work = match partial {
        Some(o) => o,
        None => {
            // the lane may have finalized <eos> in its first block and
            // retired naturally; rerun the pin assertions on a lane that
            // is provably mid-decode instead
            let l = st.admit(&ps[0], None).unwrap();
            st.cancel_lane(l).expect("freshly admitted lane is live")
        }
    };
    assert!(
        cancelled_work.gen_len <= geom.gen_len,
        "partial outcome is well-formed"
    );
    assert_eq!(st.kv_in_use(), 0, "cancel must free the KV slot");
    let after = st.prefix_chain_info(&ps[0]).unwrap();
    assert_eq!(
        after,
        baseline,
        "pool accounting must return to the warm-cache baseline \
         (resident blocks intact, refcount back to zero)"
    );
    // the freed lane is immediately admissible and decodes correctly
    let l3 = st.admit(&ps[0], None).unwrap();
    assert_eq!(st.kv_in_use(), 1);
    let mut got = None;
    let mut guard = 0;
    while !st.is_empty() {
        guard += 1;
        assert!(guard <= 10_000);
        st.step_cycle().unwrap();
        for (lane, o) in st.take_finished() {
            assert_eq!(lane, l3);
            got = Some(o);
        }
    }
    assert!(got.is_some(), "post-cancel admission decodes to completion");
    assert_eq!(st.kv_in_use(), 0);
}

#[test]
fn cancelled_lane_without_kv_slot_is_safe() {
    // cache-less methods hold no slot: cancel must not touch the pool
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(1, 0x0CA5);
    let mut st = machine(&rt, Method::Vanilla, &opts, 1);
    let lane = st.admit(&ps[0], None).unwrap();
    st.step_cycle().unwrap();
    let o = st.cancel_lane(lane).expect("vanilla never finishes early");
    assert!(o.steps >= 1, "one block of work happened");
    assert_eq!(st.kv_in_use(), 0);
    assert!(st.cancel_lane(lane).is_none(), "double cancel is a no-op");
    assert!(st.is_empty());
}

// ---------------------------------------------------------------------------
// (c) a cancelled lane never perturbs survivors
// ---------------------------------------------------------------------------

#[test]
fn cancel_does_not_perturb_surviving_lane_traces() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    for m in [Method::Vanilla, Method::Cdlm, Method::Ar] {
        let ps = prompts(2, 0xD15C ^ m.name().len() as u64);
        let weights = weights_for(&rt, m);
        let engine = Engine::new(&rt, &weights);
        let mut pool = KvPool::new(&geom, 4);
        let solo_a = engine
            .decode_serial(m, &opts, &ps[..1], &mut pool)
            .unwrap();
        // A starts; B joins one block later (its own cohort); B is then
        // "disconnected" (cancelled) while A keeps decoding
        let mut st = machine(&rt, m, &opts, 2);
        let lane_a = st.admit(&ps[0], None).unwrap();
        st.step_cycle().unwrap();
        if let Some((l, o)) = st.take_finished().pop() {
            // A early-stopped inside its first block (possible for the
            // early-stopping methods): the scenario is vacuous for this
            // seed — A provably decoded solo
            assert_eq!(l, lane_a);
            assert_eq!(o.gen, solo_a[0].gen, "{}", m.name());
            continue;
        }
        let lane_b = st.admit(&ps[1], None).unwrap();
        assert_ne!(lane_b, lane_a);
        st.step_cycle().unwrap();
        let mut got_a: Option<DecodeOutcome> = None;
        let mut b_live = true;
        for (l, o) in st.take_finished() {
            if l == lane_a {
                got_a = Some(o);
            } else {
                b_live = false; // B early-stopped before the disconnect
            }
        }
        if b_live {
            st.cancel_lane(lane_b).expect("B is mid-decode");
        }
        // Vanilla never early-stops, so the full disconnect scenario is
        // guaranteed to execute for at least that method
        assert!(
            b_live || m != Method::Vanilla,
            "vanilla lanes cannot retire early"
        );
        let mut guard = 0;
        while !st.is_empty() {
            guard += 1;
            assert!(guard <= 10_000, "{}: machine failed to drain", m.name());
            st.step_cycle().unwrap();
            for (l, o) in st.take_finished() {
                assert_eq!(l, lane_a);
                assert!(got_a.is_none(), "{}: A retired twice", m.name());
                got_a = Some(o);
            }
        }
        let got_a = got_a.expect("A retired");
        let s = &solo_a[0];
        assert_eq!(got_a.gen, s.gen, "{}: survivor gen perturbed", m.name());
        assert_eq!(
            (got_a.steps, got_a.model_calls, got_a.gen_len),
            (s.steps, s.model_calls, s.gen_len),
            "{}: survivor accounting perturbed",
            m.name()
        );
        assert_eq!(st.kv_in_use(), 0, "{}: KV leaked", m.name());
    }
}

// ---------------------------------------------------------------------------
// deadline / budget / disconnect through the router
// ---------------------------------------------------------------------------

#[test]
fn queued_deadline_expiry_aborts_without_spending_a_lane() {
    // step_delay widens block boundaries so the second request is still
    // queued when its (already expired) deadline is checked
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 1, // one lane: the second request must queue
            max_queue: 16,
            pool_capacity: 1,
            max_active: 1,
            step_delay: Duration::from_millis(25),
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let first = router.submit(request_for(Method::Vanilla, 31)).unwrap();
    let mut dead = request_for(Method::Vanilla, 32);
    dead.timeout = Some(Duration::ZERO); // expired on arrival
    let dead_handle = router.submit(dead).unwrap();
    let reason = dead_handle.wait().expect_err("expired request must abort");
    assert!(reason.contains("deadline"), "got: {reason}");
    let resp = first.wait().expect("live request unaffected");
    assert!(resp.steps >= 1);
    let h = router.health().unwrap();
    let stat = |k: &str| h.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
    assert!(
        stat("aborted_queued") >= 1.0,
        "healthz must count the queued abort: {h}"
    );
    assert_eq!(
        stat("kv_slots_in_use"),
        0.0,
        "no KV may remain held: {h}"
    );
    router.shutdown();
}

#[test]
fn max_new_tokens_truncates_with_a_finished_response() {
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 2,
            max_queue: 8,
            pool_capacity: 8,
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    // reference: the untruncated decode
    let full = router
        .submit(request_for(Method::Vanilla, 44))
        .unwrap()
        .wait()
        .expect("full decode");
    let block = router.geometry.block_size;
    let mut req = request_for(Method::Vanilla, 44);
    req.max_new_tokens = Some(block); // stop after the first boundary
    let resp = router
        .submit(req)
        .unwrap()
        .wait()
        .expect("budget stop is a successful response");
    assert!(
        full.text.starts_with(&resp.text),
        "truncated text must be a prefix of the full text \
         ({:?} vs {:?})",
        resp.text,
        full.text
    );
    if full.gen_len >= block {
        // the answer meets the budget: block 0 delivers exactly
        // `block` visible tokens (an <eos> inside it would cap gen_len
        // below the block), so the lane retires at the first boundary
        assert_eq!(
            resp.gen_len, block,
            "budget must truncate at the first block boundary"
        );
        assert!(
            resp.steps < full.steps,
            "truncation must save refinement steps ({} vs {})",
            resp.steps,
            full.steps
        );
    } else {
        // the full answer fits the budget: the budget must not distort
        // anything — identical trace to the unbudgeted decode
        assert_eq!((resp.gen_len, resp.steps), (full.gen_len, full.steps));
        assert_eq!(resp.text, full.text);
    }
    router.shutdown();
}

#[test]
fn closed_path_drops_expired_queued_requests_too() {
    // the deadline contract holds on the closed-batch worker as well:
    // enforcement happens at group dispatch instead of take_for
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            continuous: false,
            max_batch: 2,
            max_queue: 8,
            pool_capacity: 8,
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let mut dead = request_for(Method::Cdlm, 61);
    dead.timeout = Some(Duration::ZERO); // expired on arrival
    let reason = router
        .submit(dead)
        .unwrap()
        .wait()
        .expect_err("expired request must abort at dispatch");
    assert!(reason.contains("deadline"), "got: {reason}");
    let resp = router
        .submit(request_for(Method::Cdlm, 62))
        .unwrap()
        .wait()
        .expect("live request decodes normally");
    assert!(resp.steps >= 1);
    let h = router.health().unwrap();
    let stat = |k: &str| h.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
    assert!(
        stat("aborted_queued") >= 1.0,
        "healthz must count the dispatch-time abort: {h}"
    );
    router.shutdown();
}

#[test]
fn mid_stream_disconnect_cancels_and_frees_the_lane() {
    // step_delay stretches the decode so the cancel lands mid-flight
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 4,
            max_queue: 16,
            pool_capacity: 16,
            step_delay: Duration::from_millis(30),
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let victim = router.submit(request_for(Method::Vanilla, 55)).unwrap();
    let survivor = router.submit(request_for(Method::Vanilla, 56)).unwrap();
    // wait for the victim's first block, then vanish (handle drop =
    // client disconnect; cancel() makes the intent explicit)
    let mut saw_delta = false;
    while let Some(ev) = victim.next_event() {
        if matches!(ev, LaneEvent::Committed { .. }) {
            saw_delta = true;
            victim.cancel();
            break;
        }
    }
    assert!(saw_delta, "victim never streamed a block");
    let reason = victim.wait().expect_err("cancelled request must abort");
    assert!(reason.contains("cancelled"), "got: {reason}");
    drop(victim);
    let resp = survivor.wait().expect("survivor completes");
    assert!(resp.gen_len <= router.geometry.gen_len);
    let h = router.health().unwrap();
    let stat = |k: &str| h.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
    assert!(
        stat("aborted_inflight") >= 1.0,
        "healthz must count the in-flight abort: {h}"
    );
    assert_eq!(
        stat("kv_slots_in_use"),
        0.0,
        "cancelled lane must free its KV: {h}"
    );
    router.shutdown();
}
