//! SLO-preemption invariants: suspend / spill / resume on the paged
//! per-lane KV pool.
//!
//! The load-bearing pins of the preemption subsystem:
//!  * **byte-identical continuation** — for every method, a batch whose
//!    live lanes are all suspended to the cold tier and resumed at a
//!    block boundary decodes exactly (gen ids, steps, model_calls) as
//!    the uninterrupted batch: preemption must be invisible in both the
//!    trace and the accounting;
//!  * **resource round-trip** — suspending frees the lane and its pages
//!    immediately (another admission can take them), resuming
//!    re-allocates them, and the pool balances to zero after the
//!    machine drains; a parked lane that is discarded instead releases
//!    everything it still held (including its prefix-chain pin);
//!  * **paged over-subscription** — a pool whose tail-page budget could
//!    serve only `tail_budget / tail_pages_full` lanes under one-owner
//!    contiguous provisioning sustains MORE live lanes when paged, with
//!    preemption covering the shortfall.

use std::sync::Arc;

use cdlm::coordinator::{
    BatchState, DecodeOpts, DecodeOutcome, Method, SuspendedLane,
    ALL_METHODS,
};
use cdlm::runtime::{ModelWeights, Runtime};
use cdlm::tokenizer::Tokenizer;
use cdlm::workload::{self, Family};

const SEED: u64 = 0x5EED_0009;

fn prompts(n: usize, task_seed: u64) -> Vec<Vec<i32>> {
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let tok = Tokenizer::new();
    workload::generate(Family::ChainArith, n, task_seed)
        .iter()
        .map(|s| {
            workload::encode_example(
                &tok,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect()
}

fn weights_for(rt: &Runtime, m: Method) -> Arc<ModelWeights> {
    Arc::new(
        ModelWeights::load(&rt.manifest, &m.weights_for("dream")).unwrap(),
    )
}

fn machine(rt: &Arc<Runtime>, m: Method, capacity: usize) -> BatchState {
    let opts = DecodeOpts::defaults(&rt.manifest.geometry);
    BatchState::new(rt.clone(), weights_for(rt, m), m, opts, capacity)
        .unwrap()
}

/// Drive a machine batch of `prompts` to completion; when `roundtrip`
/// every live lane is suspended and immediately resumed at the first
/// block boundary. Outcomes return in admission order.
fn run_batch(
    st: &mut BatchState,
    prompts: &[Vec<i32>],
    roundtrip: bool,
) -> Vec<DecodeOutcome> {
    let mut orig = vec![usize::MAX; st.capacity()];
    let mut outs: Vec<Option<DecodeOutcome>> =
        prompts.iter().map(|_| None).collect();
    for (i, p) in prompts.iter().enumerate() {
        let lane = st.admit(p, None).unwrap();
        orig[lane] = i;
    }
    let mut first = true;
    while !st.is_empty() {
        st.step_cycle().unwrap();
        for (lane, o) in st.take_finished() {
            outs[orig[lane]] = Some(o);
        }
        if roundtrip && first {
            first = false;
            let mut parked: Vec<(SuspendedLane, usize)> = Vec::new();
            for lane in 0..st.capacity() {
                if let Some(s) = st.suspend_lane(lane) {
                    parked.push((s, orig[lane]));
                }
            }
            for (s, req) in parked {
                let lane = st.resume_lane(s).expect("provisioned resume");
                orig[lane] = req;
            }
        }
    }
    st.assert_kv_balanced();
    outs.into_iter().map(Option::unwrap).collect()
}

fn assert_same(method: Method, a: &[DecodeOutcome], b: &[DecodeOutcome]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.gen, y.gen,
            "{method:?} lane {i}: gen ids diverged after suspend/resume"
        );
        assert_eq!(x.steps, y.steps, "{method:?} lane {i}: steps diverged");
        assert_eq!(
            x.model_calls, y.model_calls,
            "{method:?} lane {i}: model_calls diverged"
        );
        assert_eq!(x.gen_len, y.gen_len);
    }
}

/// Suspend + resume at a block boundary is invisible: byte-identical
/// gen ids and identical step/model-call accounting for all methods.
#[test]
fn suspend_resume_is_byte_identical_for_every_method() {
    let rt = Arc::new(Runtime::reference(SEED));
    let ps = prompts(4, 0xAB01);
    for &m in &ALL_METHODS {
        let base = run_batch(&mut machine(&rt, m, ps.len()), &ps, false);
        let mut st = machine(&rt, m, ps.len());
        let outs = run_batch(&mut st, &ps, true);
        assert_same(m, &base, &outs);
        assert_eq!(
            st.kv_preempts(),
            st.kv_resumes(),
            "{m:?}: every preempt must have resumed"
        );
        if m.uses_kv_cache() {
            assert!(
                st.kv_preempts() > 0,
                "{m:?}: the round trip must actually spill"
            );
            assert!(st.kv_spilled_bytes() > 0);
        }
    }
}

/// Suspending frees the pool lane and its pages for another admission;
/// resuming re-allocates them; the accounting round-trips exactly.
#[test]
fn suspend_frees_resources_and_accounting_round_trips() {
    let rt = Arc::new(Runtime::reference(SEED));
    let ps = prompts(3, 0xAB02);
    let mut st = machine(&rt, Method::Cdlm, 2);
    st.admit(&ps[0], None).unwrap();
    st.admit(&ps[1], None).unwrap();
    st.step_cycle().unwrap();
    st.take_finished();
    assert_eq!(st.kv_in_use(), 2);
    let free_before = st.kv_tail_pages_free();

    let parked = st.suspend_lane(0).expect("live lane suspends");
    assert_eq!(st.kv_in_use(), 1, "suspend frees the pool lane at once");
    assert!(
        st.kv_tail_pages_free() > free_before,
        "suspend returns the lane's tail pages to the free list"
    );
    assert_eq!(st.kv_preempts(), 1);
    assert!(parked.spilled_bytes() > 0);
    assert_eq!(st.kv_spilled_bytes(), parked.spilled_bytes() as u64);

    // the freed lane is immediately admissible
    let lane = st.admit(&ps[2], None).unwrap();
    assert_eq!(st.kv_in_use(), 2);
    assert!(!st.can_resume(&parked), "no free lane while both are live");
    assert!(st.cancel_lane(lane).is_some());

    // resume restores the lane and the page accounting
    assert!(st.can_resume(&parked));
    st.resume_lane(parked).expect("free lane seats the parked state");
    assert_eq!(st.kv_resumes(), 1);
    assert_eq!(st.kv_in_use(), 2);

    while !st.is_empty() {
        st.step_cycle().unwrap();
        st.take_finished();
    }
    st.assert_kv_balanced();
}

/// A parked lane that is discarded (cancelled while suspended) releases
/// everything and reports its partial work for abort accounting.
#[test]
fn discard_suspended_releases_everything() {
    let rt = Arc::new(Runtime::reference(SEED));
    let ps = prompts(1, 0xAB00);
    let mut st = machine(&rt, Method::Cdlm, 1);
    st.admit(&ps[0], None).unwrap();
    st.step_cycle().unwrap();
    st.take_finished();
    // task seed chosen so the lane outlives its first block (verified
    // against the python accounting mirror) — the suspend is live
    let parked = st.suspend_lane(0).expect("lane outlives block 0");
    let outcome = st.discard_suspended(parked);
    assert!(outcome.steps > 0, "partial work must be reported");
    assert!(st.is_empty());
    st.assert_kv_balanced();
}

/// The pressure cooker: a pool provisioned for 2 contiguous lanes runs
/// 4 live lanes paged, trims back to the contiguous cap at the first
/// block boundary (spilling the over-admitted lanes), and still
/// produces byte-identical outcomes.
#[test]
fn paged_pool_sustains_more_live_lanes_than_contiguous_cap() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(4, 0xAB04);
    let base = run_batch(&mut machine(&rt, Method::Cdlm, ps.len()), &ps, false);

    let tail_full = (geom.seq_len - geom.prompt_len)
        .max(1)
        .div_ceil(geom.block_size.max(1));
    let mut st = BatchState::with_kv_budgets(
        rt.clone(),
        weights_for(&rt, Method::Cdlm),
        Method::Cdlm,
        opts,
        4,
        4,
        2 * tail_full,
    )
    .unwrap();
    let contiguous_cap = (st.kv_tail_page_budget() / st.kv_tail_pages_full())
        .min(st.kv_prompt_page_budget());
    assert_eq!(contiguous_cap, 2);

    let mut orig = vec![usize::MAX; st.capacity()];
    let mut outs: Vec<Option<DecodeOutcome>> =
        ps.iter().map(|_| None).collect();
    for (i, p) in ps.iter().enumerate() {
        let lane = st.admit(p, None).unwrap();
        orig[lane] = i;
    }
    let max_live = st.live_lanes();
    assert!(
        max_live > contiguous_cap,
        "paged admission must exceed the contiguous slot cap"
    );

    // run the over-admitted wave through its first block cycle, then
    // trim back to the contiguous cap (the over-admission pays its
    // debt by spilling); a free-list watermark stays armed as the
    // safety net — each unfinished lane may commit one tail page per
    // cycle
    let mut parked: Vec<(SuspendedLane, usize)> = Vec::new();
    let mut trimmed = false;
    while !st.is_empty() {
        while st.kv_tail_pages_free() < st.unfinished_lanes()
            || (trimmed && st.unfinished_lanes() > contiguous_cap)
        {
            let victim = (0..st.capacity())
                .find_map(|l| st.suspend_lane(l).map(|s| (s, orig[l])))
                .expect("pressure with no suspendable lane");
            parked.push(victim);
        }
        if st.is_empty() {
            break;
        }
        st.step_cycle().unwrap();
        trimmed = true;
        for (lane, o) in st.take_finished() {
            outs[orig[lane]] = Some(o);
        }
    }
    // task seed 0xAB04 is verified (python accounting mirror): 3 of
    // the 4 lanes outlive block 0, so the trim must have spilled
    assert!(!parked.is_empty(), "the budget must force preemption");

    // resume each parked lane solo and run it out
    for (s, req) in parked {
        assert!(st.can_resume(&s), "drained pool must seat a parked lane");
        let lane = st.resume_lane(s).expect("resume");
        orig[lane] = req;
        while !st.is_empty() {
            st.step_cycle().unwrap();
            for (l, o) in st.take_finished() {
                outs[orig[l]] = Some(o);
            }
        }
    }
    st.assert_kv_balanced();
    assert_eq!(st.kv_preempts(), st.kv_resumes());
    assert!(st.kv_preempts() > 0);

    let outs: Vec<DecodeOutcome> =
        outs.into_iter().map(Option::unwrap).collect();
    assert_same(Method::Cdlm, &base, &outs);
}

/// `resume_lane` with no free lane refuses and hands the state back
/// intact; the state remains resumable later.
#[test]
fn resume_refusal_hands_the_state_back() {
    let rt = Arc::new(Runtime::reference(SEED));
    let ps = prompts(2, 0xAB06);
    let mut st = machine(&rt, Method::Cdlm, 1);
    st.admit(&ps[0], None).unwrap();
    st.step_cycle().unwrap();
    st.take_finished();
    let parked = st.suspend_lane(0).expect("lane outlives block 0");
    st.admit(&ps[1], None).unwrap();
    let parked = match st.resume_lane(parked) {
        Ok(_) => panic!("resume must refuse while every lane is live"),
        Err(s) => s,
    };
    assert!(st.cancel_lane(0).is_some());
    assert!(st.can_resume(&parked));
    st.resume_lane(parked).expect("freed lane seats the parked state");
    while !st.is_empty() {
        st.step_cycle().unwrap();
        st.take_finished();
    }
    st.assert_kv_balanced();
}
