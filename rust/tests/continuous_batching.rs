//! Resumable block-step machine invariants.
//!
//! The load-bearing pins of the continuous-batching refactor:
//!  * **closed-batch equivalence** — for every method and batch size,
//!    a `BatchState` whose lanes are admitted together and never joined
//!    mid-flight reproduces `Engine::decode_serial`'s decode traces
//!    (gen ids, steps, model calls, gen lengths) byte-for-byte;
//!  * **mid-flight admission** — a lane admitted at a block boundary
//!    into a running batch decodes exactly as it would alone, and the
//!    in-flight lanes are unperturbed;
//!  * **slot recycling** — a retired lane's KV slot is reused by the
//!    next admission and the pool balances to zero when the machine
//!    drains.

use std::sync::Arc;

use cdlm::coordinator::{
    BatchState, DecodeOpts, DecodeOutcome, Engine, KvPool, Method,
    ALL_METHODS,
};
use cdlm::runtime::{ModelWeights, Runtime};
use cdlm::tokenizer::Tokenizer;
use cdlm::util::prop::check;
use cdlm::workload::{self, Family};

const SEED: u64 = 0x5EED_0003;

fn prompts(n: usize, task_seed: u64) -> Vec<Vec<i32>> {
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let tok = Tokenizer::new();
    workload::generate(Family::ChainArith, n, task_seed)
        .iter()
        .map(|s| {
            workload::encode_example(
                &tok,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect()
}

fn weights_for(rt: &Runtime, m: Method) -> Arc<ModelWeights> {
    Arc::new(
        ModelWeights::load(&rt.manifest, &m.weights_for("dream")).unwrap(),
    )
}

/// Drive a machine to completion with every lane admitted up front (no
/// mid-flight arrivals) and return outcomes in lane order.
fn machine_decode(
    rt: &Arc<Runtime>,
    m: Method,
    opts: &DecodeOpts,
    prompts: &[Vec<i32>],
) -> Vec<DecodeOutcome> {
    let weights = weights_for(rt, m);
    let mut st = BatchState::new(
        rt.clone(),
        weights,
        m,
        opts.clone(),
        prompts.len(),
    )
    .unwrap();
    let mut lanes = Vec::new();
    for p in prompts {
        lanes.push(st.admit(p, None).unwrap());
    }
    let mut out: Vec<Option<DecodeOutcome>> = Vec::new();
    out.resize_with(prompts.len(), || None);
    let mut guard = 0;
    while !st.is_empty() {
        guard += 1;
        assert!(guard <= 10_000, "machine failed to drain");
        st.step_cycle().unwrap();
        for (lane, o) in st.take_finished() {
            let req = lanes.iter().position(|&l| l == lane).unwrap();
            assert!(out[req].is_none(), "lane retired twice");
            out[req] = Some(o);
        }
    }
    assert_eq!(st.kv_in_use(), 0, "machine leaked KV slots");
    out.into_iter().map(Option::unwrap).collect()
}

fn traces_equal(a: &[DecodeOutcome], b: &[DecodeOutcome]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.gen == y.gen
                && x.steps == y.steps
                && x.model_calls == y.model_calls
                && x.gen_len == y.gen_len
        })
}

#[test]
fn property_machine_matches_closed_batch_for_all_methods() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    check("machine-equals-closed-batch", 12, |r| {
        // 1..=4 lanes: within one machine (the largest exported bucket)
        let n = 1 + r.index(4);
        let m = ALL_METHODS[r.index(ALL_METHODS.len())];
        let ps = prompts(n, 0xFEED ^ (n as u64) << 8 ^ r.index(1024) as u64);
        let weights = weights_for(&rt, m);
        let engine = Engine::new(&rt, &weights);
        let mut pool = KvPool::new(&geom, 8);
        let closed = engine.decode_serial(m, &opts, &ps, &mut pool).unwrap();
        let machine = machine_decode(&rt, m, &opts, &ps);
        pool.in_use() == 0 && traces_equal(&closed, &machine)
    });
}

#[test]
fn machine_matches_closed_batch_every_method_fixed_size() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(3, 0xBEE5);
    for m in ALL_METHODS {
        let weights = weights_for(&rt, m);
        let engine = Engine::new(&rt, &weights);
        let mut pool = KvPool::new(&geom, 8);
        let closed = engine.decode_serial(m, &opts, &ps, &mut pool).unwrap();
        let machine = machine_decode(&rt, m, &opts, &ps);
        assert!(
            traces_equal(&closed, &machine),
            "{}: block-step machine diverged from the closed-batch trace",
            m.name()
        );
    }
}

#[test]
fn mid_flight_admission_decodes_like_solo_for_all_methods() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(2, 0xADA7);
    for m in ALL_METHODS {
        let weights = weights_for(&rt, m);
        // solo references through the closed-batch engine
        let engine = Engine::new(&rt, &weights);
        let mut pool = KvPool::new(&geom, 4);
        let solo_a = engine
            .decode_serial(m, &opts, &ps[..1], &mut pool)
            .unwrap();
        let solo_b = engine
            .decode_serial(m, &opts, &ps[1..], &mut pool)
            .unwrap();
        // machine: admit A, advance one block, then admit B mid-flight
        let mut st = BatchState::new(
            rt.clone(),
            weights.clone(),
            m,
            opts.clone(),
            2,
        )
        .unwrap();
        let lane_a = st.admit(&ps[0], None).unwrap();
        st.step_cycle().unwrap();
        // A may already have early-stopped in its first block; if so its
        // lane index is recycled by B, so capture its outcome now
        let mut got_a: Option<DecodeOutcome> =
            st.take_finished().pop().map(|(l, o)| {
                assert_eq!(l, lane_a);
                o
            });
        let lane_b = st.admit(&ps[1], None).unwrap();
        // B is a mid-flight join only if A is still decoding; if A
        // early-stopped and retired above, B starts a drained machine
        // fresh and must NOT count as mid-flight
        let expect_mid = if got_a.is_some() { 0 } else { 1 };
        assert_eq!(st.mid_flight_admissions, expect_mid, "{}", m.name());
        let mut got_b: Option<DecodeOutcome> = None;
        let mut guard = 0;
        while !st.is_empty() {
            guard += 1;
            assert!(guard <= 10_000, "{}: machine failed to drain", m.name());
            st.step_cycle().unwrap();
            for (lane, o) in st.take_finished() {
                if lane == lane_b && got_b.is_none() {
                    got_b = Some(o);
                } else {
                    assert_eq!(lane, lane_a, "{}", m.name());
                    assert!(got_a.is_none(), "{}: lane retired twice", m.name());
                    got_a = Some(o);
                }
            }
        }
        let got_a = got_a.expect("lane A retired");
        let got_b = got_b.expect("lane B retired");
        assert!(
            traces_equal(&solo_a, std::slice::from_ref(&got_a)),
            "{}: in-flight lane perturbed by admission",
            m.name()
        );
        assert!(
            traces_equal(&solo_b, std::slice::from_ref(&got_b)),
            "{}: admitted lane diverged from its solo trace",
            m.name()
        );
        assert_eq!(st.kv_in_use(), 0, "{} leaked KV slots", m.name());
    }
}

#[test]
fn retired_lane_slot_recycles_into_next_admission() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(2, 0x51D5);
    // capacity-1 machine: B can only run by recycling A's lane + slot
    let weights = weights_for(&rt, Method::Cdlm);
    let engine = Engine::new(&rt, &weights);
    let mut pool = KvPool::new(&geom, 2);
    let solo_b = engine
        .decode_serial(Method::Cdlm, &opts, &ps[1..], &mut pool)
        .unwrap();
    let mut st = BatchState::new(
        rt.clone(),
        weights,
        Method::Cdlm,
        opts.clone(),
        1,
    )
    .unwrap();
    st.admit(&ps[0], None).unwrap();
    assert!(st.admit(&ps[1], None).is_err(), "no free lane while A runs");
    let mut guard = 0;
    while st.free_lanes() == 0 {
        guard += 1;
        assert!(guard <= 10_000);
        st.step_cycle().unwrap();
        st.take_finished();
    }
    // A retired; its lane and KV slot are free for B immediately
    let lane_b = st.admit(&ps[1], None).unwrap();
    let mut got_b = None;
    while !st.is_empty() {
        st.step_cycle().unwrap();
        for (lane, o) in st.take_finished() {
            if lane == lane_b {
                got_b = Some(o);
            }
        }
    }
    let got_b = got_b.expect("B retired");
    assert!(
        traces_equal(&solo_b, std::slice::from_ref(&got_b)),
        "recycled-lane decode diverged from solo"
    );
    assert_eq!(st.total_admissions, 2);
    assert_eq!(st.kv_in_use(), 0);
}

#[test]
fn per_lane_tau_overrides_do_not_leak_across_lanes() {
    let rt = Arc::new(Runtime::reference(SEED));
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(2, 0x7A07);
    let weights = weights_for(&rt, Method::Cdlm);
    let engine = Engine::new(&rt, &weights);
    // solo references: lane 1 at the default tau, lane 0 at tau=0
    let mut pool = KvPool::new(&geom, 4);
    let solo_default = engine
        .decode_serial(Method::Cdlm, &opts, &ps[1..], &mut pool)
        .unwrap();
    let mut opts_zero = opts.clone();
    opts_zero.tau_conf = 0.0;
    let solo_zero = engine
        .decode_serial(Method::Cdlm, &opts_zero, &ps[..1], &mut pool)
        .unwrap();
    // machine: lane 0 carries a tau=0 override, lane 1 the default —
    // both in ONE cohort, so a leak either way changes a gen trace
    let mut st = BatchState::new(
        rt.clone(),
        weights,
        Method::Cdlm,
        opts.clone(),
        2,
    )
    .unwrap();
    let lane_a = st.admit(&ps[0], Some(0.0)).unwrap();
    let lane_b = st.admit(&ps[1], None).unwrap();
    let mut got_a = None;
    let mut got_b = None;
    while !st.is_empty() {
        st.step_cycle().unwrap();
        for (lane, o) in st.take_finished() {
            if lane == lane_b {
                got_b = Some(o);
            } else if lane == lane_a {
                got_a = Some(o);
            }
        }
    }
    let got_b = got_b.expect("default-tau lane retired");
    let got_a = got_a.expect("override lane retired");
    // gen ids are pure functions of the lane's own tau (steps are
    // lockstep-coupled across the cohort, so only ids are comparable)
    assert_eq!(
        got_b.gen, solo_default[0].gen,
        "lane 0's tau override leaked onto lane 1"
    );
    assert_eq!(
        got_a.gen, solo_zero[0].gen,
        "lane 0 decoded with the batch default instead of its override"
    );
}
