//! Parallel chunk executor + KvView invariants.
//!
//! Two load-bearing properties of the PR 2 decode hot path:
//!  * the parallel chunk executor is a pure scheduling change: for any
//!    request count and any method, `Engine::decode_with_threads(N)`
//!    returns outcomes trace-for-trace identical (tokens, steps, model
//!    calls, gen lengths, order) to `Engine::decode_serial`;
//!  * decoding through zero-copy `KvView`s keeps lanes independent:
//!    a batched decode (including scheduler dead-lane padding) equals
//!    each lane's solo decode for every KV-caching method.

use cdlm::coordinator::{
    DecodeOpts, DecodeOutcome, Engine, KvPool, Method, ALL_METHODS,
};
use cdlm::runtime::{ModelWeights, Runtime};
use cdlm::tokenizer::Tokenizer;
use cdlm::util::prop::check;
use cdlm::workload::{self, Family};

const SEED: u64 = 0x5EED_0002;

fn prompts(n: usize, task_seed: u64) -> Vec<Vec<i32>> {
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let tok = Tokenizer::new();
    workload::generate(Family::ChainArith, n, task_seed)
        .iter()
        .map(|s| {
            workload::encode_example(
                &tok,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .unwrap()
            .prompt_ids
        })
        .collect()
}

fn traces_equal(a: &[DecodeOutcome], b: &[DecodeOutcome]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.gen == y.gen
                && x.steps == y.steps
                && x.model_calls == y.model_calls
                && x.gen_len == y.gen_len
        })
}

#[test]
fn parallel_chunks_match_serial_for_random_request_counts() {
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    check("parallel-equals-serial", 8, |r| {
        // 5..=12 requests: always more than the max bucket (4), so the
        // plan has several chunks and the executor actually fans out
        let n = 5 + r.index(8);
        let m = ALL_METHODS[r.index(ALL_METHODS.len())];
        let ps = prompts(n, 0xC0DE ^ n as u64);
        let w =
            ModelWeights::load(&rt.manifest, &m.weights_for("dream")).unwrap();
        let engine = Engine::new(&rt, &w);
        let mut pool = KvPool::new(&geom, 16);
        let serial = engine.decode_serial(m, &opts, &ps, &mut pool).unwrap();
        let parallel = engine
            .decode_with_threads(4, m, &opts, &ps, &mut pool)
            .unwrap();
        pool.in_use() == 0 && traces_equal(&serial, &parallel)
    });
}

#[test]
fn parallel_executor_covers_every_method_at_fixed_size() {
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let ps = prompts(7, 0xFA57); // chunks: [4 real 4, 4 real 3]
    for m in ALL_METHODS {
        let w =
            ModelWeights::load(&rt.manifest, &m.weights_for("dream")).unwrap();
        let engine = Engine::new(&rt, &w);
        let mut pool = KvPool::new(&geom, 16);
        let serial = engine.decode_serial(m, &opts, &ps, &mut pool).unwrap();
        let parallel = engine
            .decode_with_threads(2, m, &opts, &ps, &mut pool)
            .unwrap();
        assert!(
            traces_equal(&serial, &parallel),
            "{}: parallel executor changed the decode trace",
            m.name()
        );
    }
}

#[test]
fn kv_view_batched_decode_equals_solo_per_lane() {
    let rt = Runtime::reference(SEED);
    let geom = rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    // 3 distinct prompts: the bucket-4 chunk pads a dead lane, so this
    // also exercises view reads on an aliased padded slot
    let ps = prompts(3, 0xBA7C);
    for m in [Method::Cdlm, Method::Ar, Method::DllmCache, Method::FastDllmDc]
    {
        let w =
            ModelWeights::load(&rt.manifest, &m.weights_for("dream")).unwrap();
        let engine = Engine::new(&rt, &w);
        let mut pool = KvPool::new(&geom, 8);
        let batched = engine.decode_serial(m, &opts, &ps, &mut pool).unwrap();
        for (lane, p) in ps.iter().enumerate() {
            let solo = engine
                .decode_serial(m, &opts, std::slice::from_ref(p), &mut pool)
                .unwrap();
            assert_eq!(
                batched[lane].gen,
                solo[0].gen,
                "{}: lane {lane} batched != solo",
                m.name()
            );
        }
        assert_eq!(pool.in_use(), 0, "{} leaked KV slots", m.name());
    }
}
