//! Supervised shard workers: panic isolation, the stall watchdog, and
//! deterministic re-dispatch. The contract under test: a worker death
//! never strands a client (every admitted request still observes
//! exactly one terminal event), a victim that had streamed nothing is
//! replayed byte-identically on a healthy worker, and a shard that
//! burns its restart budget goes dead and degrades the router instead
//! of crash-looping.
//!
//! Runs hermetically on the deterministic reference backend.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cdlm::bench_support::drain_and_audit;
use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::{FaultPlan, GenerateRequest, Method, Router};
use cdlm::server::http::encode_user_prompt;
use cdlm::tokenizer::Tokenizer;
use cdlm::util::json::Json;
use cdlm::workload::{self, Family};

fn request_for(prompt: &str, method: Method) -> GenerateRequest {
    let tok = Tokenizer::new();
    GenerateRequest::new(
        "dream",
        method,
        encode_user_prompt(&tok, prompt, 64).unwrap(),
    )
}

fn sample_prompts(n: usize, seed: u64) -> Vec<String> {
    workload::generate(Family::ListOp, n, seed)
        .into_iter()
        .map(|s| s.prompt)
        .collect()
}

fn plan(spec: &str) -> Option<Arc<FaultPlan>> {
    Some(Arc::new(FaultPlan::parse(spec).expect("valid fault spec")))
}

fn stat(h: &Json, key: &str) -> f64 {
    h.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

/// Poll `health()` until `pred` holds (the supervisor runs on its own
/// thread, so state transitions are asynchronous to the test).
fn wait_for_health(
    router: &Router,
    what: &str,
    pred: impl Fn(&Json) -> bool,
) -> Json {
    let t0 = Instant::now();
    loop {
        let h = router.health().expect("health snapshot");
        if pred(&h) {
            return h;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "timed out waiting for {what}: {h}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn pre_commit_panic_victim_replays_byte_identically() {
    let base = RouterConfig {
        max_batch: 1,
        max_active: 1,
        max_queue: 8,
        pool_capacity: 4,
        prefix_cache: false,
        ..RouterConfig::default()
    };
    let prompt = sample_prompts(1, 0x61).pop().unwrap();

    let clean = Router::start(cdlm::artifacts_dir(), base.clone())
        .expect("router starts");
    let want = clean
        .submit(request_for(&prompt, Method::Cdlm))
        .unwrap()
        .wait()
        .expect("clean decode ok");
    clean.shutdown();

    // the worker panics before its first step cycle: the victim has
    // streamed no Committed delta, so the idempotency rule replays it
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            fault_plan: plan("panic@shard0:step0"),
            ..base
        },
    )
    .expect("router starts");
    let resp = router
        .submit(request_for(&prompt, Method::Cdlm))
        .unwrap()
        .wait()
        .expect("victim must be re-dispatched, not aborted");
    // per-lane decode traces are pure functions of the request: the
    // replay is indistinguishable from a run that never saw a panic
    assert_eq!(resp.gen_ids, want.gen_ids);
    assert_eq!(resp.text, want.text);
    assert_eq!(resp.steps, want.steps);
    assert_eq!(resp.model_calls, want.model_calls);
    let h = router.health().unwrap();
    assert_eq!(stat(&h, "shard_panics"), 1.0, "{h}");
    assert_eq!(stat(&h, "redispatched_requests"), 1.0, "{h}");
    assert_eq!(
        h.get("degraded").and_then(Json::as_bool),
        Some(false),
        "one panic within budget must not degrade the router: {h}"
    );
    let sup = h.get("supervision").expect("supervision stats");
    assert_eq!(stat(sup, "restarts"), 1.0, "{h}");
    assert_eq!(stat(sup, "dead_shards"), 0.0, "{h}");
    router.shutdown();
}

#[test]
fn every_request_sees_exactly_one_terminal_wherever_the_panic_lands() {
    // property sweep: kill the worker before step cycle k for a range
    // of k spanning pre-commit, mid-stream, and past-completion — in
    // every world each request must observe exactly one terminal event,
    // either a Finished or a shard_failure Aborted
    let prompts = sample_prompts(2, 0x62);
    for k in 0..6u64 {
        let router = Router::start(
            cdlm::artifacts_dir(),
            RouterConfig {
                max_batch: 2,
                max_active: 2,
                max_queue: 8,
                pool_capacity: 8,
                prefix_cache: false,
                fault_plan: plan(&format!("panic@shard0:step{k}")),
                ..RouterConfig::default()
            },
        )
        .expect("router starts");
        let handles: Vec<_> = prompts
            .iter()
            .map(|p| router.submit(request_for(p, Method::Cdlm)).unwrap())
            .collect();
        for (i, h) in handles.iter().enumerate() {
            let audit = drain_and_audit(h);
            assert_eq!(
                audit.terminals, 1,
                "step{k} request {i}: {} terminal events",
                audit.terminals
            );
            if let Some(reason) = &audit.abort_reason {
                assert!(
                    reason.starts_with("shard_failure"),
                    "step{k} request {i}: unexpected abort {reason:?}"
                );
            }
        }
        router.shutdown();
    }
}

#[test]
fn exhausted_restart_budget_kills_the_shard_and_degrades_the_router() {
    // two kills against a budget of one: the first respawn succeeds,
    // the second is refused and the shard goes dead
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 1,
            max_active: 1,
            max_queue: 8,
            pool_capacity: 4,
            prefix_cache: false,
            restart_budget: 1,
            fault_plan: plan("panic@shard0:step0,panic@shard0:step0"),
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let prompt = sample_prompts(1, 0x63).pop().unwrap();
    let err = router
        .submit(request_for(&prompt, Method::Cdlm))
        .unwrap()
        .wait()
        .err()
        .expect("with no healthy shard left the victim must abort");
    assert!(err.starts_with("shard_failure"), "{err}");

    let h = wait_for_health(&router, "the shard to be marked dead", |h| {
        h.get("degraded").and_then(Json::as_bool) == Some(true)
    });
    let shards = h.get("shards").and_then(Json::as_arr).expect("shards");
    assert_eq!(shards.len(), 1, "{h}");
    assert_eq!(
        shards[0].get("state").and_then(Json::as_str),
        Some("dead"),
        "{h}"
    );
    let sup = h.get("supervision").expect("supervision stats");
    assert_eq!(stat(sup, "shard_panics"), 2.0, "{h}");
    assert_eq!(stat(sup, "restarts"), 1.0, "{h}");
    assert_eq!(stat(sup, "dead_shards"), 1.0, "{h}");

    // a dead-only router refuses new work up front: 503 + Retry-After
    let err = router
        .submit(request_for(&prompt, Method::Cdlm))
        .err()
        .expect("submit against a dead fleet must be refused");
    assert_eq!(err.status(), 503, "{err}");
    assert!(err.retry_after().is_some(), "503 must carry a retry hint");
    router.shutdown();
}

#[test]
fn stalled_worker_trips_the_watchdog_and_the_request_recovers() {
    let base = RouterConfig {
        max_batch: 1,
        max_active: 1,
        max_queue: 8,
        pool_capacity: 4,
        prefix_cache: false,
        ..RouterConfig::default()
    };
    let prompt = sample_prompts(1, 0x64).pop().unwrap();

    let clean = Router::start(cdlm::artifacts_dir(), base.clone())
        .expect("router starts");
    let want = clean
        .submit(request_for(&prompt, Method::Cdlm))
        .unwrap()
        .wait()
        .expect("clean decode ok");
    clean.shutdown();

    // the worker wedges for 1.5 s against a 250 ms heartbeat deadline:
    // the watchdog must declare it lost and re-dispatch its request
    // without waiting for the sleep to return
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            watchdog_deadline: Duration::from_millis(250),
            fault_plan: plan("delay:1500@shard0:step0"),
            ..base
        },
    )
    .expect("router starts");
    let resp = router
        .submit(request_for(&prompt, Method::Cdlm))
        .unwrap()
        .wait()
        .expect("stalled victim must be re-dispatched, not aborted");
    assert_eq!(resp.gen_ids, want.gen_ids);
    assert_eq!(resp.text, want.text);
    let h = router.health().unwrap();
    assert_eq!(stat(&h, "watchdog_trips"), 1.0, "{h}");
    assert_eq!(stat(&h, "shard_panics"), 0.0, "{h}");
    assert_eq!(stat(&h, "redispatched_requests"), 1.0, "{h}");
    let sup = h.get("supervision").expect("supervision stats");
    assert_eq!(stat(sup, "restarts"), 1.0, "{h}");
    router.shutdown();
}
