//! Scalar/SIMD parity suite for `util::kernels`.
//!
//! Every kernel must be byte-identical between the dispatched ISA path
//! and the scalar fallback — for any input bit pattern (including NaNs
//! and denormals), any length (odd sizes, tails shorter than one
//! vector), and any sub-slice misalignment. The explicit `*_with`
//! entry points make both paths comparable inside one process; the
//! `CDLM_FORCE_SCALAR=1` CI leg re-runs this whole suite (and the rest
//! of the test suite) with the dispatched path itself pinned to
//! scalar, which `env_pin_is_respected_when_set` asserts.

use cdlm::util::kernels::{self, Isa};
use cdlm::util::prop::check;
use cdlm::util::rng::SplitMix64;

/// Arbitrary f32 bit patterns — NaNs, infinities, denormals included.
/// Parity is asserted on raw bits, so no pattern is off-limits.
fn rand_bits(r: &mut SplitMix64, n: usize) -> Vec<f32> {
    (0..n).map(|_| f32::from_bits(r.next_u64() as u32)).collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn env_pin_is_respected_when_set() {
    // asserts only under the CDLM_FORCE_SCALAR=1 CI leg; a no-op
    // otherwise (the OnceLock caches whatever the process started with)
    let forced = std::env::var_os(kernels::FORCE_SCALAR_ENV)
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if forced {
        assert_eq!(kernels::active_isa(), Isa::Scalar);
    }
}

#[test]
fn copy_parity_odd_lengths_and_misalignment() {
    check("copy-parity", 200, |r| {
        let n = r.index(300);
        let (so, doff) = (r.index(9), r.index(9));
        let src = rand_bits(r, so + n);
        let mut a = rand_bits(r, doff + n);
        let mut b = a.clone();
        kernels::copy_with(
            kernels::active_isa(),
            &mut a[doff..doff + n],
            &src[so..so + n],
        );
        kernels::copy_with(
            Isa::Scalar,
            &mut b[doff..doff + n],
            &src[so..so + n],
        );
        bits(&a) == bits(&b)
    });
}

#[test]
fn fill_parity_odd_lengths_and_misalignment() {
    check("fill-parity", 200, |r| {
        let n = r.index(300);
        let off = r.index(9);
        let v = f32::from_bits(r.next_u64() as u32);
        let mut a = rand_bits(r, off + n);
        let mut b = a.clone();
        kernels::fill_with(kernels::active_isa(), &mut a[off..off + n], v);
        kernels::fill_with(Isa::Scalar, &mut b[off..off + n], v);
        bits(&a) == bits(&b)
    });
}

#[test]
fn fill_i32_parity_odd_lengths_and_misalignment() {
    check("fill-i32-parity", 200, |r| {
        let n = r.index(300);
        let off = r.index(9);
        let v = r.next_u64() as i32;
        let mut a: Vec<i32> =
            (0..off + n).map(|_| r.next_u64() as i32).collect();
        let mut b = a.clone();
        kernels::fill_i32_with(kernels::active_isa(), &mut a[off..off + n], v);
        kernels::fill_i32_with(Isa::Scalar, &mut b[off..off + n], v);
        a == b
    });
}

#[test]
fn copy_2d_parity_random_strides() {
    check("copy-2d-parity", 200, |r| {
        let rows = 1 + r.index(5);
        let run = 1 + r.index(60);
        let src_stride = run + r.index(20);
        let dst_stride = run + r.index(20);
        let src_off = r.index(9);
        let dst_off = r.index(9);
        let src = rand_bits(r, src_off + rows * src_stride);
        let mut a = rand_bits(r, dst_off + rows * dst_stride);
        let mut b = a.clone();
        kernels::copy_2d_with(
            kernels::active_isa(),
            &mut a,
            dst_off,
            dst_stride,
            &src,
            src_off,
            src_stride,
            rows,
            run,
        );
        kernels::copy_2d_with(
            Isa::Scalar,
            &mut b,
            dst_off,
            dst_stride,
            &src,
            src_off,
            src_stride,
            rows,
            run,
        );
        bits(&a) == bits(&b)
    });
}

#[test]
fn fanout_rows_parity_including_len1_ar_step_shape() {
    check("fanout-parity", 200, |r| {
        // geometry-shaped: l_n layers, bs lanes, h_n heads, len
        // positions, dh features; case 0 of every 4 pins the ar_step
        // shape (len=1)
        let l_n = 1 + r.index(4);
        let bs = 1 + r.index(3);
        let h_n = 1 + r.index(4);
        let len = if r.index(4) == 0 { 1 } else { 1 + r.index(12) };
        let dh = 1 + r.index(9);
        let lane = r.index(bs);
        let row = h_n * len * dh;
        let lstride = bs * row;
        let n = l_n * lstride;
        let k0 = rand_bits(r, n);
        let v0 = rand_bits(r, n);
        let (mut ka, mut va) = (k0.clone(), v0.clone());
        let (mut kb, mut vb) = (k0, v0);
        kernels::fanout_rows_with(
            kernels::active_isa(),
            &mut ka,
            &mut va,
            lane * row,
            row,
            l_n,
            lstride,
        );
        kernels::fanout_rows_with(
            Isa::Scalar,
            &mut kb,
            &mut vb,
            lane * row,
            row,
            l_n,
            lstride,
        );
        bits(&ka) == bits(&kb) && bits(&va) == bits(&vb)
    });
}

#[test]
fn fanout_rows_matches_strided_scalar_scatter() {
    // the historical replicate_ctx loop, kept here as the semantic
    // reference: fan (head 0, feature 0) context slots across layers.
    // On producer-shaped buffers (everything else zero) the row-wise
    // kernel must reproduce it byte-for-byte.
    check("fanout-vs-scatter", 100, |r| {
        let l_n = 1 + r.index(4);
        let bs = 1 + r.index(3);
        let h_n = 1 + r.index(4);
        let len = 1 + r.index(12);
        let dh = 1 + r.index(9);
        let lane = r.index(bs);
        let row = h_n * len * dh;
        let lstride = bs * row;
        let n = l_n * lstride;
        // producer-shaped: only layer-0 (head 0, feature 0) context
        // slots of this lane are nonzero
        let mut k = vec![0.0f32; n];
        let v = vec![0.0f32; n];
        for p in 0..len {
            k[lane * row + p * dh] = (r.below(1 << 24)) as f32;
        }
        let (mut ka, mut va) = (k.clone(), v.clone());
        kernels::fanout_rows(&mut ka, &mut va, lane * row, row, l_n, lstride);
        // reference scatter
        let (mut kb, mut vb) = (k, v);
        let mut off = lane * row;
        for _p in 0..len {
            let c = kb[off];
            vb[off] = c;
            let mut o = off + lstride;
            for _l in 1..l_n {
                kb[o] = c;
                vb[o] = c;
                o += lstride;
            }
            off += dh;
        }
        bits(&ka) == bits(&kb) && bits(&va) == bits(&vb)
    });
}

#[test]
fn spill_unspill_roundtrip_and_byte_layout() {
    check("spill-roundtrip", 200, |r| {
        let n = r.index(300);
        let src = rand_bits(r, n);
        let mut out = Vec::new();
        kernels::spill_f32_le(&mut out, &src);
        // byte layout is exactly the element-wise to_le_bytes stream
        let reference: Vec<u8> =
            src.iter().flat_map(|x| x.to_le_bytes()).collect();
        if out != reference {
            return false;
        }
        let mut back = vec![0.0f32; n];
        kernels::unspill_f32_le(&out, &mut back);
        bits(&back) == bits(&src)
    });
}

#[test]
fn dispatched_isa_is_reported_and_valid() {
    let isa = kernels::active_isa();
    assert!(matches!(isa, Isa::Avx2 | Isa::Neon | Isa::Scalar));
    assert!(["avx2", "neon", "scalar"].contains(&isa.label()));
}
