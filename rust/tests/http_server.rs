//! HTTP front-end robustness: socket timeouts must keep idle and
//! slow-loris connections from pinning the bounded handler pool, and
//! the `"stream": true` chunked NDJSON wire protocol must deliver
//! deltas whose concatenation is byte-identical to the one-shot
//! response.
//!
//! Runs hermetically on the reference backend; the server is started on
//! an ephemeral port via `serve_on`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::Router;
use cdlm::server::{self, http::ServerConfig};
use cdlm::util::json::Json;

fn start_server(io_timeout: Duration) -> SocketAddr {
    start_server_with(
        RouterConfig {
            max_batch: 2,
            max_queue: 8,
            pool_capacity: 8,
            ..RouterConfig::default()
        },
        io_timeout,
    )
}

fn start_server_with(cfg: RouterConfig, io_timeout: Duration) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let router =
        Router::start(cdlm::artifacts_dir(), cfg).expect("router starts");
    std::thread::spawn(move || {
        let _ = server::serve_on(
            listener,
            router,
            ServerConfig {
                addr: String::new(), // already bound
                default_backbone: "dream".into(),
                io_timeout,
                ..ServerConfig::default()
            },
        );
    });
    addr
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .expect("request written");
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn http_post(addr: SocketAddr, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .expect("request written");
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn body_of(resp: &str) -> &str {
    resp.split("\r\n\r\n").nth(1).unwrap_or("")
}

/// Decode a chunked-transfer body into its payload bytes.
fn dechunk(mut body: &str) -> String {
    let mut out = String::new();
    loop {
        let Some((len_line, rest)) = body.split_once("\r\n") else { break };
        let len =
            usize::from_str_radix(len_line.trim(), 16).expect("chunk length");
        if len == 0 {
            break;
        }
        out.push_str(&rest[..len]);
        // skip the chunk payload and its trailing CRLF
        body = &rest[len + 2..];
    }
    out
}

#[test]
fn streamed_deltas_match_the_one_shot_response_over_the_wire() {
    let addr = start_server(Duration::from_secs(30));
    let req = r#"{"prompt": "q:3*4+5=?", "method": "cdlm"}"#;
    let one_shot = http_post(addr, "/generate", req);
    assert!(one_shot.starts_with("HTTP/1.1 200"), "{one_shot:?}");
    let one_shot = Json::parse(body_of(&one_shot)).expect("response json");
    let want_text =
        one_shot.get("text").and_then(Json::as_str).expect("text");

    let streamed = http_post(
        addr,
        "/generate",
        r#"{"prompt": "q:3*4+5=?", "method": "cdlm", "stream": true}"#,
    );
    assert!(streamed.starts_with("HTTP/1.1 200"), "{streamed:?}");
    assert!(
        streamed.contains("Transfer-Encoding: chunked"),
        "{streamed:?}"
    );
    assert!(
        streamed.contains("application/x-ndjson"),
        "{streamed:?}"
    );
    let payload = dechunk(body_of(&streamed));
    let events: Vec<Json> = payload
        .lines()
        .map(|l| Json::parse(l).expect("event line json"))
        .collect();
    assert!(events.len() >= 3, "admitted + >=1 delta + terminal");
    let kind = |e: &Json| {
        e.get("event").and_then(Json::as_str).unwrap_or("").to_string()
    };
    assert_eq!(kind(&events[0]), "admitted");
    let mut concat = String::new();
    let mut deltas = 0;
    for e in &events[..events.len() - 1] {
        if kind(e) == "delta" {
            concat.push_str(e.get("text").and_then(Json::as_str).unwrap());
            deltas += 1;
        }
    }
    assert!(deltas >= 1, "at least one block delta");
    let last = events.last().unwrap();
    assert_eq!(
        kind(last),
        "finished",
        "exactly one terminal event, last: {last}"
    );
    assert_eq!(
        concat,
        want_text,
        "concatenated deltas must equal the one-shot text"
    );
    assert_eq!(
        last.get("text").and_then(Json::as_str),
        Some(want_text),
        "terminal event carries the full text"
    );
    assert!(
        last.get("ttft_ms").and_then(Json::as_f64).is_some(),
        "terminal event carries the socket-observed TTFT"
    );
}

#[test]
fn streamed_deadline_abort_is_a_terminal_event_line() {
    let addr = start_server(Duration::from_secs(30));
    // a microscopic (250us) deadline: the request almost certainly
    // expires before admission and must die with a terminal `aborted`
    // line on the stream, not a dropped connection
    let streamed = http_post(
        addr,
        "/generate",
        r#"{"prompt": "q:1+1=?", "method": "cdlm", "stream": true,
            "timeout_ms": 0.25}"#,
    );
    assert!(streamed.starts_with("HTTP/1.1 200"), "{streamed:?}");
    let payload = dechunk(body_of(&streamed));
    let last = payload
        .lines()
        .last()
        .map(|l| Json::parse(l).expect("event json"))
        .expect("at least one event line");
    let kind = last.get("event").and_then(Json::as_str).unwrap_or("");
    // the request usually expires in the queue, but a fast worker can
    // still finish it first — both are legal terminal events
    assert!(
        kind == "aborted" || kind == "finished",
        "missing terminal event: {last}"
    );
}

#[test]
fn idle_connections_cannot_pin_the_handler_pool() {
    let addr = start_server(Duration::from_millis(250));
    // saturate the 8-thread handler pool with idle (slow-loris) clients
    // that never send a byte
    let _loris: Vec<TcpStream> = (0..8)
        .map(|_| TcpStream::connect(addr).expect("loris connect"))
        .collect();
    // give the pool time to hand every idle socket to a handler
    std::thread::sleep(Duration::from_millis(100));
    // a real request must still complete: the idle sockets' reads time
    // out and release their handler threads
    let t0 = Instant::now();
    let resp = http_get(addr, "/healthz");
    assert!(
        resp.starts_with("HTTP/1.1 200"),
        "healthz behind 8 idle clients failed: {resp:?}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(8),
        "request starved for {:?}",
        t0.elapsed()
    );
}

#[test]
fn event_loop_sustains_64_concurrent_streaming_connections() {
    // the acceptance bar for the nonblocking front door: 64 streaming
    // clients multiplexed on the single event-loop thread (the old
    // blocking pool would deadlock at 9 held connections)
    let addr = start_server_with(
        RouterConfig {
            max_batch: 4,
            max_queue: 128,
            pool_capacity: 16,
            max_active: 8,
            ..RouterConfig::default()
        },
        Duration::from_secs(60),
    );
    let body = r#"{"prompt": "q:2+2=?", "method": "cdlm", "stream": true}"#;
    let mut conns: Vec<TcpStream> = Vec::new();
    for _ in 0..64 {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        )
        .expect("request written");
        conns.push(s);
    }
    // every socket is open before any response is consumed, so the
    // server holds all 64 connections concurrently
    for mut s in conns {
        let mut out = String::new();
        let _ = s.read_to_string(&mut out);
        assert!(out.starts_with("HTTP/1.1 200"), "{out:?}");
        let payload = dechunk(body_of(&out));
        let last = payload
            .lines()
            .last()
            .map(|l| Json::parse(l).expect("event json"))
            .expect("terminal event");
        assert_eq!(
            last.get("event").and_then(Json::as_str),
            Some("finished"),
            "stream must end in a terminal finished event: {last}"
        );
    }
}

#[test]
fn saturated_admission_answers_429_with_retry_after_on_the_wire() {
    // per-client cap of 1 with a slow decode: the first request holds
    // its fairness permit while the second (same client) must bounce
    let addr = start_server_with(
        RouterConfig {
            max_batch: 1,
            max_active: 1,
            max_queue: 8,
            pool_capacity: 4,
            max_per_client: 1,
            step_delay: Duration::from_millis(100),
            ..RouterConfig::default()
        },
        Duration::from_secs(30),
    );
    let mut held = TcpStream::connect(addr).expect("connect");
    held.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = r#"{"prompt": "q:9*9=?", "method": "cdlm", "stream": true,
                   "client_id": "cap"}"#;
    write!(
        held,
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n\
         {body}",
        body.len()
    )
    .expect("request written");
    // the stream header is only written once submit() succeeded, so
    // seeing any bytes proves the permit is held
    let mut buf = [0u8; 64];
    let n = held.read(&mut buf).expect("stream header");
    assert!(n > 0, "held request must be admitted first");

    let resp = http_post(
        addr,
        "/generate",
        r#"{"prompt": "q:1+2=?", "method": "cdlm", "client_id": "cap"}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 429"), "{resp:?}");
    assert!(resp.contains("Retry-After:"), "{resp:?}");
    drop(held); // hang up: the server cancels the in-flight lane
}

#[test]
fn drain_answers_503_with_retry_after_then_shuts_down() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 2,
            max_queue: 8,
            pool_capacity: 8,
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = stop.clone();
    let srv = std::thread::spawn(move || {
        server::serve_on_until(
            listener,
            router,
            ServerConfig {
                addr: String::new(), // already bound
                default_backbone: "dream".into(),
                io_timeout: Duration::from_secs(10),
                ..ServerConfig::default()
            },
            stop_flag,
        )
    });
    // a connection accepted *before* the drain begins but whose request
    // lands *after* must get the admission answer, not a dropped socket
    let mut late = TcpStream::connect(addr).expect("connect");
    late.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(late, "POST /generate HTTP/1.1\r\nHost: t\r\n")
        .expect("partial header written");
    std::thread::sleep(Duration::from_millis(100)); // loop registers it
    stop.store(true, Ordering::SeqCst);
    std::thread::sleep(Duration::from_millis(100)); // drain begins
    let body = r#"{"prompt": "q:1+1=?", "method": "cdlm"}"#;
    write!(
        late,
        "Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .expect("request completed");
    let mut out = String::new();
    let _ = late.read_to_string(&mut out);
    assert!(out.starts_with("HTTP/1.1 503"), "{out:?}");
    assert!(out.contains("Retry-After:"), "{out:?}");
    // with its last connection answered, the event loop joins the shard
    // workers and returns cleanly
    let t0 = Instant::now();
    while !srv.is_finished() && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(srv.is_finished(), "server must return after the drain");
    srv.join().unwrap().expect("clean shutdown");
}

#[test]
fn idle_connection_is_dropped_after_the_timeout() {
    let addr = start_server(Duration::from_millis(200));
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // send nothing: the server must hang up after its io_timeout
    // instead of holding the handler forever
    let t0 = Instant::now();
    let mut buf = [0u8; 16];
    let n = s.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server must close the idle connection silently");
    assert!(
        t0.elapsed() < Duration::from_secs(8),
        "idle connection held for {:?}",
        t0.elapsed()
    );
}
