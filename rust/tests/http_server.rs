//! HTTP front-end robustness: socket timeouts must keep idle and
//! slow-loris connections from pinning the bounded handler pool, and
//! the `"stream": true` chunked NDJSON wire protocol must deliver
//! deltas whose concatenation is byte-identical to the one-shot
//! response.
//!
//! Runs hermetically on the reference backend; the server is started on
//! an ephemeral port via `serve_on`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::Router;
use cdlm::server::{self, http::ServerConfig};
use cdlm::util::json::Json;

fn start_server(io_timeout: Duration) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 2,
            max_queue: 8,
            pool_capacity: 8,
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    std::thread::spawn(move || {
        let _ = server::serve_on(
            listener,
            router,
            ServerConfig {
                addr: String::new(), // already bound
                default_backbone: "dream".into(),
                io_timeout,
            },
        );
    });
    addr
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .expect("request written");
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn http_post(addr: SocketAddr, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .expect("request written");
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn body_of(resp: &str) -> &str {
    resp.split("\r\n\r\n").nth(1).unwrap_or("")
}

/// Decode a chunked-transfer body into its payload bytes.
fn dechunk(mut body: &str) -> String {
    let mut out = String::new();
    loop {
        let Some((len_line, rest)) = body.split_once("\r\n") else { break };
        let len =
            usize::from_str_radix(len_line.trim(), 16).expect("chunk length");
        if len == 0 {
            break;
        }
        out.push_str(&rest[..len]);
        // skip the chunk payload and its trailing CRLF
        body = &rest[len + 2..];
    }
    out
}

#[test]
fn streamed_deltas_match_the_one_shot_response_over_the_wire() {
    let addr = start_server(Duration::from_secs(30));
    let req = r#"{"prompt": "q:3*4+5=?", "method": "cdlm"}"#;
    let one_shot = http_post(addr, "/generate", req);
    assert!(one_shot.starts_with("HTTP/1.1 200"), "{one_shot:?}");
    let one_shot = Json::parse(body_of(&one_shot)).expect("response json");
    let want_text =
        one_shot.get("text").and_then(Json::as_str).expect("text");

    let streamed = http_post(
        addr,
        "/generate",
        r#"{"prompt": "q:3*4+5=?", "method": "cdlm", "stream": true}"#,
    );
    assert!(streamed.starts_with("HTTP/1.1 200"), "{streamed:?}");
    assert!(
        streamed.contains("Transfer-Encoding: chunked"),
        "{streamed:?}"
    );
    assert!(
        streamed.contains("application/x-ndjson"),
        "{streamed:?}"
    );
    let payload = dechunk(body_of(&streamed));
    let events: Vec<Json> = payload
        .lines()
        .map(|l| Json::parse(l).expect("event line json"))
        .collect();
    assert!(events.len() >= 3, "admitted + >=1 delta + terminal");
    let kind = |e: &Json| {
        e.get("event").and_then(Json::as_str).unwrap_or("").to_string()
    };
    assert_eq!(kind(&events[0]), "admitted");
    let mut concat = String::new();
    let mut deltas = 0;
    for e in &events[..events.len() - 1] {
        if kind(e) == "delta" {
            concat.push_str(e.get("text").and_then(Json::as_str).unwrap());
            deltas += 1;
        }
    }
    assert!(deltas >= 1, "at least one block delta");
    let last = events.last().unwrap();
    assert_eq!(
        kind(last),
        "finished",
        "exactly one terminal event, last: {last}"
    );
    assert_eq!(
        concat,
        want_text,
        "concatenated deltas must equal the one-shot text"
    );
    assert_eq!(
        last.get("text").and_then(Json::as_str),
        Some(want_text),
        "terminal event carries the full text"
    );
    assert!(
        last.get("ttft_ms").and_then(Json::as_f64).is_some(),
        "terminal event carries the socket-observed TTFT"
    );
}

#[test]
fn streamed_deadline_abort_is_a_terminal_event_line() {
    let addr = start_server(Duration::from_secs(30));
    // a microscopic (250us) deadline: the request almost certainly
    // expires before admission and must die with a terminal `aborted`
    // line on the stream, not a dropped connection
    let streamed = http_post(
        addr,
        "/generate",
        r#"{"prompt": "q:1+1=?", "method": "cdlm", "stream": true,
            "timeout_ms": 0.25}"#,
    );
    assert!(streamed.starts_with("HTTP/1.1 200"), "{streamed:?}");
    let payload = dechunk(body_of(&streamed));
    let last = payload
        .lines()
        .last()
        .map(|l| Json::parse(l).expect("event json"))
        .expect("at least one event line");
    let kind = last.get("event").and_then(Json::as_str).unwrap_or("");
    // the request usually expires in the queue, but a fast worker can
    // still finish it first — both are legal terminal events
    assert!(
        kind == "aborted" || kind == "finished",
        "missing terminal event: {last}"
    );
}

#[test]
fn idle_connections_cannot_pin_the_handler_pool() {
    let addr = start_server(Duration::from_millis(250));
    // saturate the 8-thread handler pool with idle (slow-loris) clients
    // that never send a byte
    let _loris: Vec<TcpStream> = (0..8)
        .map(|_| TcpStream::connect(addr).expect("loris connect"))
        .collect();
    // give the pool time to hand every idle socket to a handler
    std::thread::sleep(Duration::from_millis(100));
    // a real request must still complete: the idle sockets' reads time
    // out and release their handler threads
    let t0 = Instant::now();
    let resp = http_get(addr, "/healthz");
    assert!(
        resp.starts_with("HTTP/1.1 200"),
        "healthz behind 8 idle clients failed: {resp:?}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(8),
        "request starved for {:?}",
        t0.elapsed()
    );
}

#[test]
fn idle_connection_is_dropped_after_the_timeout() {
    let addr = start_server(Duration::from_millis(200));
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // send nothing: the server must hang up after its io_timeout
    // instead of holding the handler forever
    let t0 = Instant::now();
    let mut buf = [0u8; 16];
    let n = s.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server must close the idle connection silently");
    assert!(
        t0.elapsed() < Duration::from_secs(8),
        "idle connection held for {:?}",
        t0.elapsed()
    );
}
