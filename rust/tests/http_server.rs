//! HTTP front-end robustness: socket timeouts must keep idle and
//! slow-loris connections from pinning the bounded handler pool.
//!
//! Runs hermetically on the reference backend; the server is started on
//! an ephemeral port via `serve_on`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::Router;
use cdlm::server::{self, http::ServerConfig};

fn start_server(io_timeout: Duration) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let router = Router::start(
        cdlm::artifacts_dir(),
        RouterConfig {
            max_batch: 2,
            max_queue: 8,
            pool_capacity: 8,
            ..RouterConfig::default()
        },
    )
    .expect("router starts");
    std::thread::spawn(move || {
        let _ = server::serve_on(
            listener,
            router,
            ServerConfig {
                addr: String::new(), // already bound
                default_backbone: "dream".into(),
                io_timeout,
            },
        );
    });
    addr
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .expect("request written");
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

#[test]
fn idle_connections_cannot_pin_the_handler_pool() {
    let addr = start_server(Duration::from_millis(250));
    // saturate the 8-thread handler pool with idle (slow-loris) clients
    // that never send a byte
    let _loris: Vec<TcpStream> = (0..8)
        .map(|_| TcpStream::connect(addr).expect("loris connect"))
        .collect();
    // give the pool time to hand every idle socket to a handler
    std::thread::sleep(Duration::from_millis(100));
    // a real request must still complete: the idle sockets' reads time
    // out and release their handler threads
    let t0 = Instant::now();
    let resp = http_get(addr, "/healthz");
    assert!(
        resp.starts_with("HTTP/1.1 200"),
        "healthz behind 8 idle clients failed: {resp:?}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(8),
        "request starved for {:?}",
        t0.elapsed()
    );
}

#[test]
fn idle_connection_is_dropped_after_the_timeout() {
    let addr = start_server(Duration::from_millis(200));
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // send nothing: the server must hang up after its io_timeout
    // instead of holding the handler forever
    let t0 = Instant::now();
    let mut buf = [0u8; 16];
    let n = s.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server must close the idle connection silently");
    assert!(
        t0.elapsed() < Duration::from_secs(8),
        "idle connection held for {:?}",
        t0.elapsed()
    );
}
