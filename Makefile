# Repo-level build/CI entry points. `make ci` mirrors the CI workflow;
# `make verify` mirrors the tier-1 gate exactly.

CARGO ?= cargo

.PHONY: ci verify fmt clippy build test test-scalar smoke check-baseline shard-smoke chaos-smoke hotpath preempt-smoke check-pjrt bench clean

ci: fmt clippy build test test-scalar smoke check-baseline shard-smoke chaos-smoke hotpath preempt-smoke check-pjrt

# Tier-1 verify (the regression gate), exactly as the roadmap states it.
verify:
	$(CARGO) build --release && $(CARGO) test -q

fmt:
	$(CARGO) fmt --all --check

clippy:
	$(CARGO) clippy --workspace --all-targets -- -D warnings

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# The whole suite again with the util::kernels scalar fallback pinned,
# so the non-SIMD path cannot bit-rot on AVX2-capable machines. The
# golden-trace tests double as scalar-vs-SIMD parity at the decode
# level: traces must be byte-identical under both settings.
test-scalar:
	CDLM_FORCE_SCALAR=1 $(CARGO) test -q

# Hermetic end-to-end smoke: eval two methods on the reference backend.
smoke:
	$(CARGO) run --release --bin cdlm -- eval --methods cdlm,ar --n 8

# Deterministic accounting gate: the same bench CI runs, hard-failing on
# any drift of per-cell steps/model_calls from BENCH_baseline.json. The
# gate runs at --replicas 1 AND --replicas 4 against the same committed
# baseline, so the routed (closed-loop through the sharded dispatcher)
# cells also pin shard-count invariance. To regenerate after an
# intentional accounting change:
#   python3 python/tools/gen_bench_baseline.py
# The third leg re-runs the --replicas 4 grid with a seeded fault plan
# armed: a worker is killed before its first commit mid-run, and the
# routed solo-cohort cells must still reproduce EXACTLY the committed
# baseline integers — supervised re-dispatch is required to be
# invisible in the accounting.
check-baseline:
	$(CARGO) run --release --bin cdlm -- bench --methods all --batches 1,4,8 --n 8 --replicas 1 --out BENCH_decode.json --check-baseline BENCH_baseline.json
	$(CARGO) run --release --bin cdlm -- bench --methods all --batches 1,4,8 --n 8 --replicas 4 --out BENCH_decode_r4.json --check-baseline BENCH_baseline.json
	$(CARGO) run --release --bin cdlm -- bench --methods all --batches 1,4,8 --n 8 --replicas 4 --fault-seed 7 --out BENCH_decode_faulted.json --check-baseline BENCH_baseline.json

# Sharded-serving smoke: 1-vs-N replica arrival trace + saturation
# burst (schema cdlm.bench.shard/v1). Record only — invariance is
# gated by check-baseline, admission semantics by the test suite.
shard-smoke:
	$(CARGO) run --release --bin cdlm -- bench --scenario shard --method cdlm --n 24 --distinct 6 --replicas 4 --arrival-ms 2 --out BENCH_shard.json

# Chaos recovery gate: one arrival trace run clean and again under a
# seeded fault plan (a worker panic before any commit plus a delayed
# step; schema cdlm.bench.chaos/v1). Unlike the other scenario smokes
# this one asserts: exactly one terminal event per request, finished
# faulted responses byte-identical to their clean twins, aborts only
# with supervision reasons, and the plan must actually fire.
chaos-smoke:
	$(CARGO) run --release --bin cdlm -- bench --scenario chaos --method cdlm --n 24 --distinct 6 --replicas 4 --arrival-ms 2 --fault-seed 7 --out BENCH_chaos.json

# Steady-state decode-step microbench + allocation gate (schema
# cdlm.bench.hotpath/v2): drives every method's machine policy
# functions with a reused step arena and HARD-FAILS if any steady-state
# gated window performs a heap allocation. Latency/tokens-per-s fields
# and the per-kernel GB/s cells (with the selected util::kernels ISA
# path) are advisory trend data — compare BENCH_hotpath.json across
# commits; only the allocation count gates.
hotpath:
	$(CARGO) run --release --bin cdlm -- bench --scenario hotpath --methods all --batches 1,4 --repeats 6 --out BENCH_hotpath.json

# SLO-preemption pressure cooker (schema cdlm.bench.preempt/v1): an
# over-subscribed paged pool (contiguous cap 2 lanes) runs waves of 4,
# trims to the cap by spilling lanes to the host cold tier at the first
# block boundary, and resumes them after the survivors drain. HARD
# gates: over-subscription happened, resumes == preempts > 0 with
# spilled bytes, and every preempted request byte-identical to its
# uninterrupted twin. Resume-latency percentiles are trend data only.
preempt-smoke:
	$(CARGO) run --release --bin cdlm -- bench --scenario preempt --method cdlm --n 16 --out BENCH_preempt.json

# Type-check the off-by-default PJRT seam against the vendored xla API
# stub (the `pjrt` feature gates real execution behind the real crate).
check-pjrt:
	$(CARGO) check --workspace --all-targets --features pjrt

bench:
	$(CARGO) bench

clean:
	$(CARGO) clean
