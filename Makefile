# Repo-level build/CI entry points. `make ci` mirrors the CI workflow;
# `make verify` mirrors the tier-1 gate exactly.

CARGO ?= cargo

.PHONY: ci verify fmt clippy build test smoke bench clean

ci: fmt clippy build test smoke

# Tier-1 verify (the regression gate), exactly as the roadmap states it.
verify:
	$(CARGO) build --release && $(CARGO) test -q

fmt:
	$(CARGO) fmt --all --check

clippy:
	$(CARGO) clippy --workspace --all-targets -- -D warnings

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Hermetic end-to-end smoke: eval two methods on the reference backend.
smoke:
	$(CARGO) run --release --bin cdlm -- eval --methods cdlm,ar --n 8

bench:
	$(CARGO) bench

clean:
	$(CARGO) clean
